//! GEMM kernel backends: the pluggable micro-kernel layer of the BFP
//! stack.
//!
//! * **Shared band loop** — [`run_tiled_band`] owns the cache-tiled,
//!   register-blocked traversal (`TILE_J`-wide output strips, blocks
//!   combined in ascending contraction order, one exact power-of-two
//!   scale per block pair). Kernels differ *only* in their integer
//!   block-dot inner loops ([`BlockDot`]), so every backend is
//!   bit-identical by construction: integer MACs are exact, and the
//!   f64 accumulation order is fixed by the shared loop.
//! * **Grouped entry** — [`GemmKernel::run_band_macs_grouped`] is the
//!   weight-stationary batch entry: one shared weight, many
//!   [`GroupedMacSegment`]s (each a different activation matrix plus
//!   its own disjoint MAC-plane slice). The provided default simply
//!   iterates the per-segment [`GemmKernel::run_band_macs`], so every
//!   backend is grouped-vs-per-op bit-identical *by construction* —
//!   a backend may override it to hoist weight-plane loads across
//!   segments, but each segment's MACs are exact independent `i32`s,
//!   so the contract stays: same bits as running the segments one by
//!   one. The batch scheduler uses this entry to stream each encoded
//!   weight through memory once per band tile per group.
//! * **Backends** — [`ScalarTiledKernel`] (portable reference, runs
//!   every plane-layout pair), [`AutovecKernel`] (unrolled,
//!   autovectorization-friendly `i8`/nibble loops for narrow planes),
//!   on x86_64 [`Avx2Kernel`] (explicit AVX2 widening MACs) and
//!   [`Avx512Kernel`] (512-bit VNNI `vpdpwssd` where available, with
//!   an exact `vpmaddwd` twin), and on aarch64 [`NeonKernel`]
//!   (`smull`/`sdot` lanes). SIMD backends register only when runtime
//!   feature detection passes.
//!
//! # Dispatch: three tiers
//!
//! [`active_kernel`]`(x, w, block, shape)` resolves every GEMM's
//! backend through the process-wide [`registry`], in strict priority
//! order:
//!
//! 1. **Env override** — `BOOSTERS_KERNEL` (parsed once by
//!    [`crate::util::kernel_override`]) forces one backend for the
//!    whole process. A forced backend that the host cannot run warns
//!    once and falls back; a forced backend that cannot run one
//!    specific operand combination degrades down the preference chain
//!    for that dispatch only. The override outranks the autotune
//!    table: an operator pinning a kernel always wins.
//! 2. **Autotune table** — under `auto`, the registry consults the
//!    host-tuned table loaded once at init ([`autotune`] module docs
//!    for the JSON schema and the `BOOSTERS_AUTOTUNE` path override;
//!    produced by `bench_quantize --autotune`). The key is coarse —
//!    (layout pair, block bucket, M×N×K bucket) — and a hit is
//!    honored only if the named backend is registered and supports
//!    the combination. Missing or corrupt tables warn (once) and
//!    drop to tier 3; an absent default artifact is silent.
//! 3. **Static default** — the preference chain (most specialized
//!    first, scalar always last): the first registered backend that
//!    supports the [`PlaneLayout`] pair at this block size. Never
//!    panics, never changes bits.
//!
//! Nibble-packed operands ([`PlaneLayout::I4Packed`]) are consumed
//! directly: kernels sign-extend nibbles in the inner loop instead of
//! unpacking to bytes first, so the 4-bit formats get the storage
//! density *and* keep a dense inner loop.

pub mod autotune;
pub mod autovec;
#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "x86_64")]
pub mod avx512;
#[cfg(target_arch = "aarch64")]
pub mod neon;
pub mod scalar;

pub use autotune::{AutotuneTable, GemmShape, KernelOpCounts, TableBuilder};
pub use autovec::AutovecKernel;
#[cfg(target_arch = "x86_64")]
pub use avx2::Avx2Kernel;
#[cfg(target_arch = "x86_64")]
pub use avx512::Avx512Kernel;
#[cfg(target_arch = "aarch64")]
pub use neon::NeonKernel;
pub use scalar::ScalarTiledKernel;

use super::packed::{nib_at, BfpMatrix, PlaneLayout};
use crate::util::KernelChoice;
use std::sync::OnceLock;

/// Output-strip width of the tiled band loop (f64 accumulators held in
/// registers while one activation block streams the weight plane).
pub(crate) const TILE_J: usize = 8;

/// Largest block size whose narrow (nibble or i8) block MAC provably
/// fits an i32 accumulator (|product| <= 2^14, so 2^16 terms stay
/// under 2^30).
pub(crate) const MAX_I32_BLOCK: usize = 1 << 16;

/// Exact 2^shift in f64. Bit-construction covers the normal range;
/// `powi` handles the subnormal tail identically to the scalar path.
#[inline]
pub(crate) fn exp2_f64(shift: i32) -> f64 {
    if (-1022..=1023).contains(&shift) {
        f64::from_bits(((shift + 1023) as u64) << 52)
    } else {
        (2.0f64).powi(shift)
    }
}

/// One contiguous band of a GEMM: activation rows `r0 .. r0 + rows` of
/// `x` against every packed column of `w`, writing the band's slice of
/// the output. `xsh`/`wsh` are the precomputed per-block scale shifts
/// ([`super::gemm::band_shifts`]) of the full operands.
pub struct BandTask<'a> {
    pub x: &'a BfpMatrix,
    pub w: &'a BfpMatrix,
    pub xsh: &'a [i32],
    pub wsh: &'a [i32],
    pub r0: usize,
    pub rows: usize,
    pub out: &'a mut [f32],
}

/// One contiguous band of the **integer MAC pass** — the first half of
/// the split (MAC → decode) execution pipeline. The kernel fills
/// `macs` with the exact per-(row, column, k-block) block MACs of
/// activation rows `r0 .. r0 + rows`, laid out band-locally as
/// `macs[(i * n + j) * kb + k]` (`i` relative to `r0`, `n = w.rows`,
/// `kb = x.blocks_per_row`). No scale shifts are applied here — the
/// decode stage ([`decode_mac_band`]) replays the f64 accumulation
/// later, possibly on another thread while the next batch's MACs run.
/// Only valid for operand pairs where [`mac_split_supported`] holds
/// (narrow planes, block MAC provably fits `i32`).
pub struct MacBandTask<'a> {
    pub x: &'a BfpMatrix,
    pub w: &'a BfpMatrix,
    pub r0: usize,
    pub rows: usize,
    pub macs: &'a mut [i32],
}

/// One activation segment of a **grouped** (weight-stationary) MAC
/// band: a band-local slice of one member op's activation rows plus
/// the op's own MAC plane to fill. A grouped band task is a sequence
/// of these segments against one shared weight — see
/// [`GemmKernel::run_band_macs_grouped`].
pub struct GroupedMacSegment<'a> {
    /// The member op's encoded activation operand.
    pub x: &'a BfpMatrix,
    /// First activation row of this segment within `x`.
    pub r0: usize,
    /// Activation rows in this segment.
    pub rows: usize,
    /// The segment's band-local slice of the member op's MAC plane,
    /// laid out exactly like [`MacBandTask::macs`].
    pub macs: &'a mut [i32],
}

/// A band-level GEMM micro-kernel. Implementations must be pure
/// functions of the task (no scheduling decisions) and must accumulate
/// each output element's blocks in ascending contraction order so that
/// every kernel is bit-compatible with the scalar reference — which
/// the shared [`run_tiled_band`] loop guarantees for kernels built on
/// [`BlockDot`].
pub trait GemmKernel: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether this backend has an inner loop for the given operand
    /// plane-layout pair at the given block size (narrow backends
    /// require blocks whose MAC fits their i32 accumulators, and the
    /// AVX2 backend requires runtime feature support). The registry
    /// only dispatches supported combinations — so the kernel name
    /// reported in stats and bench metadata is the backend that
    /// actually executed. The scalar kernel supports everything.
    fn supports(&self, x: PlaneLayout, w: PlaneLayout, block: usize) -> bool;

    fn run_band(&self, task: BandTask<'_>);

    /// The integer-MAC half of the split pipeline: same traversal as
    /// [`GemmKernel::run_band`], but block MACs are **stored** instead
    /// of scaled-and-accumulated, so the f32 decode can run as its own
    /// pipeline stage. Callers must check [`mac_split_supported`]
    /// first. The default runs the portable generic loop; SIMD
    /// backends override it with their own block-dot inner loops —
    /// either way the stored MACs are the exact integers, so the
    /// decode stage reproduces the fused path bit-for-bit.
    fn run_band_macs(&self, task: MacBandTask<'_>) {
        run_band_macs_generic(task);
    }

    /// The **grouped** (weight-stationary) form of
    /// [`GemmKernel::run_band_macs`]: one shared weight operand against
    /// a sequence of activation segments from different member ops of a
    /// same-weight group. The contract is pure iteration — each segment
    /// is exactly one `run_band_macs` call with the shared `w` — so the
    /// stored MACs are bit-identical to per-op execution **by
    /// construction** (every stored MAC is an independent exact `i32`;
    /// no accumulator ever crosses a segment). What grouping changes is
    /// *locality*: consecutive segments stream the same weight
    /// mantissa/exponent planes, so the weight is loaded through the
    /// cache hierarchy once per band task instead of once per op.
    ///
    /// The default inherits every backend's own tuned inner loops via
    /// its `run_band_macs` override — backends need no grouped-specific
    /// code, and a backend that *can* do better (e.g. pinning the
    /// weight panel in registers across segments) may override this
    /// while preserving the stored-MAC contract. Callers must check
    /// [`mac_split_supported`] per segment's layout pair, same as the
    /// per-op entry.
    fn run_band_macs_grouped(&self, w: &BfpMatrix, segments: &mut [GroupedMacSegment<'_>]) {
        for seg in segments.iter_mut() {
            self.run_band_macs(MacBandTask {
                x: seg.x,
                w,
                r0: seg.r0,
                rows: seg.rows,
                macs: &mut *seg.macs,
            });
        }
    }
}

/// Read access to one mantissa plane by absolute value index — the
/// abstraction that lets the portable kernel run any layout pair,
/// nibble-packed included.
pub(crate) trait PlaneAccess: Copy {
    /// True when |values| < 2^7: block MACs fit i32 accumulators for
    /// blocks up to [`MAX_I32_BLOCK`].
    const NARROW: bool;
    fn get(self, i: usize) -> i32;
}

impl PlaneAccess for &[i8] {
    const NARROW: bool = true;

    #[inline]
    fn get(self, i: usize) -> i32 {
        self[i] as i32
    }
}

impl PlaneAccess for &[i16] {
    const NARROW: bool = false;

    #[inline]
    fn get(self, i: usize) -> i32 {
        self[i] as i32
    }
}

/// Nibble-packed plane view: value `i` lives in byte `i / 2`, low
/// nibble for even `i`, high for odd.
#[derive(Clone, Copy)]
pub(crate) struct NibblePlane<'a>(pub &'a [u8]);

impl PlaneAccess for NibblePlane<'_> {
    const NARROW: bool = true;

    #[inline]
    fn get(self, i: usize) -> i32 {
        nib_at(self.0, i) as i32
    }
}

/// Construct the right [`BlockDot`] view for an operand plane pair and
/// run `$body` with it bound to `$d`: byte/i16 pairs get the
/// zipped-subslice [`scalar::SliceDot`] (the shape LLVM
/// autovectorizes), nibble-involved pairs the index-generic
/// [`scalar::AccessDot`] over [`NibblePlane`] views. This is the
/// single home of plane-view construction — the scalar band kernel
/// and [`crate::bfp::gemm::packed_dot`] both expand it, so a new
/// mantissa layout plugs into both in exactly one place.
macro_rules! with_plane_pair_dot {
    ($x:expr, $w:expr, |$d:ident| $body:expr) => {{
        use $crate::bfp::kernels::scalar::{AccessDot, SliceDot};
        use $crate::bfp::kernels::NibblePlane;
        use $crate::bfp::packed::MantissaPlane as PlanePair;
        match ($x, $w) {
            // Byte/i16 pairs: the original zipped-subslice loops.
            (PlanePair::I8(a), PlanePair::I8(w)) => {
                let $d = SliceDot {
                    a: a.as_slice(),
                    w: w.as_slice(),
                };
                $body
            }
            (PlanePair::I8(a), PlanePair::I16(w)) => {
                let $d = SliceDot {
                    a: a.as_slice(),
                    w: w.as_slice(),
                };
                $body
            }
            (PlanePair::I16(a), PlanePair::I8(w)) => {
                let $d = SliceDot {
                    a: a.as_slice(),
                    w: w.as_slice(),
                };
                $body
            }
            (PlanePair::I16(a), PlanePair::I16(w)) => {
                let $d = SliceDot {
                    a: a.as_slice(),
                    w: w.as_slice(),
                };
                $body
            }
            // Nibble-involved pairs: index-generic access.
            (PlanePair::I4Packed(a), PlanePair::I4Packed(w)) => {
                let $d = AccessDot {
                    a: NibblePlane(a),
                    w: NibblePlane(w),
                };
                $body
            }
            (PlanePair::I4Packed(a), PlanePair::I8(w)) => {
                let $d = AccessDot {
                    a: NibblePlane(a),
                    w: w.as_slice(),
                };
                $body
            }
            (PlanePair::I4Packed(a), PlanePair::I16(w)) => {
                let $d = AccessDot {
                    a: NibblePlane(a),
                    w: w.as_slice(),
                };
                $body
            }
            (PlanePair::I8(a), PlanePair::I4Packed(w)) => {
                let $d = AccessDot {
                    a: a.as_slice(),
                    w: NibblePlane(w),
                };
                $body
            }
            (PlanePair::I16(a), PlanePair::I4Packed(w)) => {
                let $d = AccessDot {
                    a: a.as_slice(),
                    w: NibblePlane(w),
                };
                $body
            }
        }
    }};
}
pub(crate) use with_plane_pair_dot;

/// Integer dot products over block pairs at absolute plane offsets —
/// the only part of a kernel that differs between backends. `dot` must
/// return the exact integer MAC of the block pair; exactness is what
/// makes every backend bit-identical under [`run_tiled_band`].
pub(crate) trait BlockDot {
    fn dot(&self, a_off: usize, w_off: usize, len: usize) -> i64;

    /// Register-blocked form: one activation block against four weight
    /// blocks. The default just calls [`BlockDot::dot`] four times;
    /// backends override it to keep four accumulators live.
    #[inline]
    fn dot4(&self, a_off: usize, w_offs: [usize; 4], len: usize) -> [i64; 4] {
        [
            self.dot(a_off, w_offs[0], len),
            self.dot(a_off, w_offs[1], len),
            self.dot(a_off, w_offs[2], len),
            self.dot(a_off, w_offs[3], len),
        ]
    }
}

/// The shared cache-tiled band loop (see module docs): `TILE_J`-wide
/// output strips, four weight blocks per inner step, blocks combined
/// into the f64 accumulator in ascending contraction order with one
/// exact power-of-two scale per block pair. All kernels run this exact
/// traversal, so results depend only on each backend's (exact) integer
/// block MACs — i.e. not on the backend at all.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_tiled_band<D: BlockDot>(
    d: &D,
    xsh: &[i32],
    wsh: &[i32],
    r0: usize,
    band_rows: usize,
    n: usize,
    kb: usize,
    b: usize,
    out: &mut [f32],
) {
    let stride = kb * b;
    let mut acc = [0.0f64; TILE_J];
    for i in 0..band_rows {
        let gi = r0 + i;
        let xrow = gi * stride;
        let xs = &xsh[gi * kb..(gi + 1) * kb];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let tj = TILE_J.min(n - j0);
            acc[..tj].fill(0.0);
            for k in 0..kb {
                let a_off = xrow + k * b;
                let sx = xs[k];
                let mut jj = 0;
                while jj + 4 <= tj {
                    let j = j0 + jj;
                    let o0 = j * stride + k * b;
                    let (o1, o2, o3) = (o0 + stride, o0 + 2 * stride, o0 + 3 * stride);
                    let macs = d.dot4(a_off, [o0, o1, o2, o3], b);
                    for (q, &mac) in macs.iter().enumerate() {
                        if mac != 0 {
                            acc[jj + q] += mac as f64 * exp2_f64(sx + wsh[(j + q) * kb + k]);
                        }
                    }
                    jj += 4;
                }
                while jj < tj {
                    let j = j0 + jj;
                    let mac = d.dot(a_off, j * stride + k * b, b);
                    if mac != 0 {
                        acc[jj] += mac as f64 * exp2_f64(sx + wsh[j * kb + k]);
                    }
                    jj += 1;
                }
            }
            for (jj, &v) in acc[..tj].iter().enumerate() {
                orow[j0 + jj] = v as f32;
            }
            j0 += tj;
        }
    }
}

/// Whether the (MAC, decode) split is valid for an operand pair: both
/// planes narrow (i4/i8 mantissas) so every block MAC provably fits an
/// `i32`, and the block small enough that the worst-case sum does too.
/// Wide (i16) pairs keep the fused [`run_tiled_band`] path — the
/// decode stage then only publishes their already-decoded outputs.
pub(crate) fn mac_split_supported(x: PlaneLayout, w: PlaneLayout, block: usize) -> bool {
    fn narrow(l: PlaneLayout) -> bool {
        matches!(l, PlaneLayout::I4Packed | PlaneLayout::I8)
    }
    narrow(x) && narrow(w) && block <= MAX_I32_BLOCK
}

/// Shared MAC-pass loop: the exact traversal of [`run_tiled_band`],
/// but each block MAC is stored into `macs[(i * n + j) * kb + k]`
/// instead of being scaled and accumulated. Because the fused loop's
/// f64 accumulator for an output element only ever sees that element's
/// own block MACs in ascending `k` order, replaying the stored MACs in
/// ascending `k` (see [`decode_mac_band`]) reproduces the fused result
/// bit-for-bit. The `i32` store is exact: callers gate on
/// [`mac_split_supported`], which bounds every MAC well below `2^31`.
pub(crate) fn run_tiled_band_macs<D: BlockDot>(
    d: &D,
    r0: usize,
    band_rows: usize,
    n: usize,
    kb: usize,
    b: usize,
    macs: &mut [i32],
) {
    let stride = kb * b;
    for i in 0..band_rows {
        let xrow = (r0 + i) * stride;
        let mrow = &mut macs[i * n * kb..(i + 1) * n * kb];
        let mut j0 = 0;
        while j0 < n {
            let tj = TILE_J.min(n - j0);
            for k in 0..kb {
                let a_off = xrow + k * b;
                let mut jj = 0;
                while jj + 4 <= tj {
                    let j = j0 + jj;
                    let o0 = j * stride + k * b;
                    let (o1, o2, o3) = (o0 + stride, o0 + 2 * stride, o0 + 3 * stride);
                    let quad = d.dot4(a_off, [o0, o1, o2, o3], b);
                    for (q, &mac) in quad.iter().enumerate() {
                        mrow[(j + q) * kb + k] = mac as i32;
                    }
                    jj += 4;
                }
                while jj < tj {
                    let j = j0 + jj;
                    mrow[j * kb + k] = d.dot(a_off, j * stride + k * b, b) as i32;
                    jj += 1;
                }
            }
            j0 += tj;
        }
    }
}

/// Decode stage: scale-shift + f64-accumulate a band of stored MACs
/// into f32 outputs. Per output element this performs exactly the adds
/// the fused loop would have — same operands, same ascending `k`
/// order, same `if mac != 0` skip — so the result is bit-identical to
/// [`run_tiled_band`] regardless of how either pass was band-sharded
/// (elements never share an accumulator). `macs` and `out` are
/// band-local (rows `r0 .. r0 + rows`); the shift vectors are global.
#[allow(clippy::too_many_arguments)]
pub(crate) fn decode_mac_band(
    macs: &[i32],
    xsh: &[i32],
    wsh: &[i32],
    r0: usize,
    rows: usize,
    n: usize,
    kb: usize,
    out: &mut [f32],
) {
    for i in 0..rows {
        let xs = &xsh[(r0 + i) * kb..(r0 + i + 1) * kb];
        let mrow = &macs[i * n * kb..(i + 1) * n * kb];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let mj = &mrow[j * kb..(j + 1) * kb];
            let wj = &wsh[j * kb..(j + 1) * kb];
            let mut acc = 0.0f64;
            for k in 0..kb {
                let mac = mj[k];
                if mac != 0 {
                    acc += mac as f64 * exp2_f64(xs[k] + wj[k]);
                }
            }
            *o = acc as f32;
        }
    }
}

/// Portable MAC pass used by [`GemmKernel::run_band_macs`]'s default
/// implementation and as the fallback when a SIMD backend's feature or
/// layout re-check fails at the band level.
pub(crate) fn run_band_macs_generic(t: MacBandTask<'_>) {
    let n = t.w.rows;
    let kb = t.x.blocks_per_row;
    let b = t.x.fmt.block_size;
    with_plane_pair_dot!(&t.x.mantissas, &t.w.mantissas, |d| run_tiled_band_macs(
        &d, t.r0, t.rows, n, kb, b, t.macs
    ));
}

// --- registry --------------------------------------------------------------

static SCALAR: ScalarTiledKernel = ScalarTiledKernel;
static AUTOVEC: AutovecKernel = AutovecKernel;
#[cfg(target_arch = "x86_64")]
static AVX2: Avx2Kernel = Avx2Kernel;
#[cfg(target_arch = "x86_64")]
static AVX512: Avx512Kernel = Avx512Kernel;
#[cfg(target_arch = "aarch64")]
static NEON: NeonKernel = NeonKernel;

static WARNED_AVX2: std::sync::Once = std::sync::Once::new();
static WARNED_AVX512: std::sync::Once = std::sync::Once::new();
static WARNED_NEON: std::sync::Once = std::sync::Once::new();

/// The set of GEMM backends runnable on this host, the one the
/// `BOOSTERS_KERNEL` override and runtime feature detection resolved
/// to, and the host's autotune table (if any). Built once per process
/// by [`registry`].
pub struct KernelRegistry {
    /// Runnable backends in preference order (most specialized first,
    /// the scalar fallback always last).
    kernels: Vec<&'static dyn GemmKernel>,
    preferred: &'static dyn GemmKernel,
    choice: KernelChoice,
    table: Option<AutotuneTable>,
}

impl KernelRegistry {
    fn build(choice: KernelChoice) -> Self {
        Self::build_with(choice, autotune::load())
    }

    /// Construction from explicit parts — the test seam that lets a
    /// hand-written table (or its absence) drive dispatch without
    /// touching the process environment or filesystem.
    fn build_with(choice: KernelChoice, table: Option<AutotuneTable>) -> Self {
        let mut kernels: Vec<&'static dyn GemmKernel> = Vec::with_capacity(4);
        if let Some(k) = detect_avx512() {
            kernels.push(k);
        }
        if let Some(k) = detect_avx2() {
            kernels.push(k);
        }
        if let Some(k) = detect_neon() {
            kernels.push(k);
        }
        kernels.push(&AUTOVEC);
        kernels.push(&SCALAR);
        let auto = kernels[0];
        let preferred: &'static dyn GemmKernel = match choice {
            KernelChoice::Scalar => &SCALAR,
            KernelChoice::Autovec => &AUTOVEC,
            KernelChoice::Avx2 => forced_or_loud_fallback(detect_avx2(), "avx2", &WARNED_AVX2, auto),
            KernelChoice::Avx512 => {
                forced_or_loud_fallback(detect_avx512(), "avx512", &WARNED_AVX512, auto)
            }
            KernelChoice::Neon => forced_or_loud_fallback(detect_neon(), "neon", &WARNED_NEON, auto),
            KernelChoice::Auto => auto,
        };
        Self {
            kernels,
            preferred,
            choice,
            table,
        }
    }

    /// Every backend runnable on this host, preference order. Tests
    /// and benches iterate this to pin bit-identity per backend.
    pub fn all(&self) -> &[&'static dyn GemmKernel] {
        &self.kernels
    }

    /// The backend the override + detection resolved to — the kernel
    /// identity the exec stats and bench artifacts report.
    pub fn preferred(&self) -> &'static dyn GemmKernel {
        self.preferred
    }

    /// The parsed `BOOSTERS_KERNEL` choice this registry was built
    /// from.
    pub fn choice(&self) -> KernelChoice {
        self.choice
    }

    /// Backend lookup by [`GemmKernel::name`].
    pub fn by_name(&self, name: &str) -> Option<&'static dyn GemmKernel> {
        self.kernels.iter().copied().find(|k| k.name() == name)
    }

    /// The autotune table dispatch consults under `auto`, if one
    /// loaded.
    pub fn autotune(&self) -> Option<&AutotuneTable> {
        self.table.as_ref()
    }

    /// Resolve a programmatic choice (e.g.
    /// [`crate::exec::ServiceConfig`]'s kernel field) to a runnable
    /// backend; `Auto` resolves to the registry's preferred kernel,
    /// and an unavailable SIMD choice falls back to it **loudly**
    /// (warned once), matching the `BOOSTERS_KERNEL` env-path
    /// contract.
    pub fn resolve(&self, choice: KernelChoice) -> &'static dyn GemmKernel {
        match choice {
            KernelChoice::Auto => self.preferred,
            KernelChoice::Scalar => &SCALAR,
            KernelChoice::Autovec => &AUTOVEC,
            KernelChoice::Avx2 => {
                forced_or_loud_fallback(detect_avx2(), "avx2", &WARNED_AVX2, self.preferred)
            }
            KernelChoice::Avx512 => {
                forced_or_loud_fallback(detect_avx512(), "avx512", &WARNED_AVX512, self.preferred)
            }
            KernelChoice::Neon => {
                forced_or_loud_fallback(detect_neon(), "neon", &WARNED_NEON, self.preferred)
            }
        }
    }

    /// Per-operand dispatch: the preferred backend where it supports
    /// the layout pair at this block size, else the next backend down
    /// the preference chain that does (the scalar kernel closes the
    /// chain). This is the shape-blind tier-3 path; shape-aware
    /// callers go through [`KernelRegistry::select_shaped`].
    pub fn select(&self, x: PlaneLayout, w: PlaneLayout, block: usize) -> &'static dyn GemmKernel {
        self.select_from(self.preferred, x, w, block)
    }

    /// Shape-aware dispatch (module docs, tiers 1-3): a forced
    /// `BOOSTERS_KERNEL` choice outranks the autotune table; under
    /// `auto`, a table hit whose backend is registered and supports
    /// the combination wins; everything else falls to the static
    /// preference chain.
    pub fn select_shaped(
        &self,
        x: PlaneLayout,
        w: PlaneLayout,
        block: usize,
        shape: GemmShape,
    ) -> &'static dyn GemmKernel {
        if self.choice == KernelChoice::Auto {
            let hit = self.table.as_ref().and_then(|t| t.lookup(x, w, block, shape));
            if let Some(k) = hit.and_then(|name| self.by_name(name)) {
                if k.supports(x, w, block) {
                    return k;
                }
            }
        }
        self.select(x, w, block)
    }

    /// [`KernelRegistry::select`] starting from an explicit backend —
    /// how a forced kernel (tests, [`crate::exec::BatchGemm`]) degrades
    /// on combinations it cannot run instead of panicking.
    pub fn select_from(
        &self,
        first: &'static dyn GemmKernel,
        x: PlaneLayout,
        w: PlaneLayout,
        block: usize,
    ) -> &'static dyn GemmKernel {
        if first.supports(x, w, block) {
            return first;
        }
        // Backend names are unique, so this is identity without fat-
        // pointer comparison.
        let start = self
            .kernels
            .iter()
            .position(|k| k.name() == first.name())
            .map(|i| i + 1)
            .unwrap_or(0);
        self.kernels[start..]
            .iter()
            .copied()
            .find(|k| k.supports(x, w, block))
            .unwrap_or(&SCALAR)
    }
}

/// The single home of the loud forced-SIMD fallback: the detected
/// backend, or `fallback` with a once-per-process stderr warning (one
/// `Once` per requested backend, shared between the `BOOSTERS_KERNEL`
/// env path and the programmatic [`KernelRegistry::resolve`] path so
/// the two can never diverge in policy or message).
fn forced_or_loud_fallback(
    detected: Option<&'static dyn GemmKernel>,
    requested: &str,
    warned: &'static std::sync::Once,
    fallback: &'static dyn GemmKernel,
) -> &'static dyn GemmKernel {
    detected.unwrap_or_else(|| {
        warned.call_once(|| {
            eprintln!(
                "[boosters] {requested} kernel requested but not available on this host; \
                 falling back to the {} kernel",
                fallback.name()
            );
        });
        fallback
    })
}

#[cfg(target_arch = "x86_64")]
fn detect_avx2() -> Option<&'static dyn GemmKernel> {
    if std::arch::is_x86_feature_detected!("avx2") {
        Some(&AVX2)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_avx2() -> Option<&'static dyn GemmKernel> {
    None
}

#[cfg(target_arch = "x86_64")]
fn detect_avx512() -> Option<&'static dyn GemmKernel> {
    if avx512::avx512_available() {
        Some(&AVX512)
    } else {
        None
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_avx512() -> Option<&'static dyn GemmKernel> {
    None
}

#[cfg(target_arch = "aarch64")]
fn detect_neon() -> Option<&'static dyn GemmKernel> {
    if neon::neon_available() {
        Some(&NEON)
    } else {
        None
    }
}

#[cfg(not(target_arch = "aarch64"))]
fn detect_neon() -> Option<&'static dyn GemmKernel> {
    None
}

static REGISTRY: OnceLock<KernelRegistry> = OnceLock::new();

/// The process-wide kernel registry: `BOOSTERS_KERNEL` + feature
/// detection resolved once, on first GEMM dispatch.
pub fn registry() -> &'static KernelRegistry {
    REGISTRY.get_or_init(|| KernelRegistry::build(crate::util::kernel_override()))
}

/// The kernel the runtime dispatches for one operand combination at
/// one problem shape — the single swap point the whole GEMM stack
/// (single-op path, batch scheduler, benches) routes through. See the
/// module docs for the three dispatch tiers.
pub fn active_kernel(
    x: PlaneLayout,
    w: PlaneLayout,
    block: usize,
    shape: GemmShape,
) -> &'static dyn GemmKernel {
    registry().select_shaped(x, w, block, shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_always_has_a_scalar_fallback() {
        let reg = registry();
        assert!(!reg.all().is_empty());
        assert_eq!(reg.all().last().unwrap().name(), "scalar-tiled");
        // The scalar kernel runs everything — every layout pair at any
        // block size, including blocks past the i32-accumulator bound.
        let scalar = reg.by_name("scalar-tiled").unwrap();
        for x in [PlaneLayout::I4Packed, PlaneLayout::I8, PlaneLayout::I16] {
            for w in [PlaneLayout::I4Packed, PlaneLayout::I8, PlaneLayout::I16] {
                for block in [64usize, MAX_I32_BLOCK * 2] {
                    assert!(scalar.supports(x, w, block));
                    // Whatever dispatch returns, it must support the
                    // combination it will be reported as executing.
                    assert!(reg.select(x, w, block).supports(x, w, block));
                }
            }
        }
        // Oversized blocks dispatch to the scalar kernel even where a
        // narrow backend covers the layout pair, keeping the reported
        // kernel identity truthful.
        assert_eq!(
            reg.select(PlaneLayout::I8, PlaneLayout::I8, MAX_I32_BLOCK * 2).name(),
            "scalar-tiled"
        );
    }

    #[test]
    fn resolve_maps_choices_to_runnable_backends() {
        let reg = registry();
        assert_eq!(reg.resolve(KernelChoice::Scalar).name(), "scalar-tiled");
        assert_eq!(reg.resolve(KernelChoice::Autovec).name(), "autovec");
        // Auto resolves to the preferred backend; Avx2 resolves to a
        // runnable backend on every host (itself or the fallback).
        assert_eq!(reg.resolve(KernelChoice::Auto).name(), reg.preferred().name());
        // Every SIMD choice resolves to a runnable backend on every
        // host (itself where detected, the loud fallback otherwise).
        for choice in [KernelChoice::Avx2, KernelChoice::Avx512, KernelChoice::Neon] {
            let k = reg.resolve(choice);
            assert!(reg.by_name(k.name()).is_some(), "{choice:?} -> {}", k.name());
        }
    }

    fn small_table(kernel: &str, bucket: &str) -> AutotuneTable {
        let text = format!(
            r#"{{"schema": "boosters-autotune-v1", "entries": [
                {{"x": "i8", "w": "i8", "block_bucket": "b16",
                  "mnk_bucket": {bucket:?}, "kernel": {kernel:?}}}]}}"#
        );
        AutotuneTable::parse(&text).expect("hand-written table parses")
    }

    #[test]
    fn autotune_table_forces_the_pick_per_bucket() {
        // A hand-written table that pins small-shape i8 GEMMs to the
        // scalar backend must win under `auto` dispatch...
        let reg = KernelRegistry::build_with(
            KernelChoice::Auto,
            Some(small_table("scalar-tiled", "small")),
        );
        let small = GemmShape::new(8, 8, 32);
        let large = GemmShape::new(512, 512, 512);
        let (i8p, b) = (PlaneLayout::I8, 16usize);
        assert_eq!(reg.select_shaped(i8p, i8p, b, small).name(), "scalar-tiled");
        // ...while unmapped buckets fall through to the static tier.
        assert_eq!(reg.select_shaped(i8p, i8p, b, large).name(), reg.select(i8p, i8p, b).name());
        // A different mapped bucket picks its own backend.
        let reg =
            KernelRegistry::build_with(KernelChoice::Auto, Some(small_table("autovec", "large")));
        assert_eq!(reg.select_shaped(i8p, i8p, b, large).name(), "autovec");
        assert_eq!(reg.select_shaped(i8p, i8p, b, small).name(), reg.select(i8p, i8p, b).name());
    }

    #[test]
    fn env_override_outranks_the_autotune_table() {
        // A forced choice ignores the table entirely (tier 1 beats
        // tier 2): the table says autovec, the override says scalar.
        let reg = KernelRegistry::build_with(
            KernelChoice::Scalar,
            Some(small_table("autovec", "small")),
        );
        let small = GemmShape::new(8, 8, 32);
        assert_eq!(
            reg.select_shaped(PlaneLayout::I8, PlaneLayout::I8, 16, small).name(),
            "scalar-tiled"
        );
    }

    #[test]
    fn bogus_or_absent_tables_fall_back_to_static_dispatch() {
        // A table naming an unregistered backend is a hint we cannot
        // honor — dispatch degrades to the static tier, never panics.
        let reg =
            KernelRegistry::build_with(KernelChoice::Auto, Some(small_table("gpu-magic", "small")));
        let small = GemmShape::new(8, 8, 32);
        let (i8p, b) = (PlaneLayout::I8, 16usize);
        assert_eq!(reg.select_shaped(i8p, i8p, b, small).name(), reg.select(i8p, i8p, b).name());
        // No table at all: select_shaped is exactly select.
        let reg = KernelRegistry::build_with(KernelChoice::Auto, None);
        assert!(reg.autotune().is_none());
        for x in [PlaneLayout::I4Packed, PlaneLayout::I8, PlaneLayout::I16] {
            for block in [16usize, 64, MAX_I32_BLOCK * 2] {
                assert_eq!(
                    reg.select_shaped(x, x, block, small).name(),
                    reg.select(x, x, block).name()
                );
            }
        }
        // A selected backend always supports what it is reported to
        // have executed, shape-aware or not.
        let picked = registry().select_shaped(i8p, i8p, 16, GemmShape::new(3, 5, 7));
        assert!(picked.supports(i8p, i8p, 16));
    }

    #[test]
    fn wide_pairs_fall_back_to_scalar_from_any_start() {
        let reg = registry();
        for k in reg.all() {
            let picked = reg.select_from(*k, PlaneLayout::I16, PlaneLayout::I16, 64);
            assert!(
                picked.supports(PlaneLayout::I16, PlaneLayout::I16, 64),
                "{} -> {}",
                k.name(),
                picked.name()
            );
        }
    }

    #[test]
    fn exp2_matches_powi_across_the_exponent_budget() {
        // Encoded exponents live in [-512, 511]; pair shifts span about
        // [-1052, 1022], crossing into the subnormal range.
        for shift in (-1060..=1030).step_by(7) {
            assert_eq!(
                exp2_f64(shift).to_bits(),
                (2.0f64).powi(shift).to_bits(),
                "shift {shift}"
            );
        }
    }
}
