//! Explicit AVX2 backend (x86_64 only): widening integer MACs over
//! narrow planes via `vpmovsxbw` + `vpmaddwd`.
//!
//! Registered by the kernel registry only when
//! `is_x86_feature_detected!("avx2")` holds; `run_band` re-checks and
//! falls back to the scalar kernel (loudly, in debug builds) if it is
//! ever dispatched on a host without AVX2, so the unsafe
//! `#[target_feature]` calls below are never reached undetected.
//!
//! # Exactness = bit-identity
//!
//! Every step is exact integer arithmetic: i8 (or sign-extended
//! nibble) products fit i16 pairs fit i32 lanes — for blocks up to
//! [`MAX_I32_BLOCK`] the per-lane accumulators provably cannot wrap
//! (`2^12` iterations x `2^15` per `vpmaddwd` pair-sum < `2^27`).
//! Integer addition is associative, so the lane-parallel sums equal
//! the scalar kernel's sequential sums bit-for-bit once combined; the
//! shared tiled band loop fixes the f64 combination order. Larger
//! blocks (which need i64 accumulation) delegate to the scalar
//! kernel.
//!
//! Nibble-packed operands are consumed directly from the byte stream:
//! low nibbles sign-extend via `((b & 0xF) ^ 8) - 8` on 32 lanes at
//! once, high nibbles via a 4-bit shift first — no unpack buffer.

use super::{
    run_band_macs_generic, run_tiled_band, run_tiled_band_macs, BandTask, BlockDot, GemmKernel,
    MacBandTask, MAX_I32_BLOCK,
};
use crate::bfp::packed::{nib_hi, nib_lo, MantissaPlane, PlaneLayout};
use std::arch::x86_64::*;

/// The runtime-detected AVX2 widening kernel (see module docs).
pub struct Avx2Kernel;

/// Horizontal sum of eight i32 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let lo = _mm256_castsi256_si128(v);
    let hi = _mm256_extracti128_si256::<1>(v);
    let s = _mm_add_epi32(lo, hi);
    // [a,b,c,d] -> [c,d,a,b] -> pairwise -> [b',a',...] -> total in lane 0.
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01_00_11_10>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b10_11_00_01>(s));
    _mm_cvtsi128_si32(s)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i8], w: &[i8]) -> i32 {
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 16 <= n {
        let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i) as *const __m128i));
        let vw = _mm256_cvtepi8_epi16(_mm_loadu_si128(w.as_ptr().add(i) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vw));
        i += 16;
    }
    let mut sum = hsum_epi32(acc);
    while i < n {
        sum += a[i] as i32 * w[i] as i32;
        i += 1;
    }
    sum
}

/// Widen 32 i8 lanes and multiply-accumulate against another 32 into
/// the i32 accumulator.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn mac_i8x32(acc: __m256i, x: __m256i, y: __m256i) -> __m256i {
    let x_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(x));
    let y_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(y));
    let x_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(x));
    let y_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(y));
    let acc = _mm256_add_epi32(acc, _mm256_madd_epi16(x_lo, y_lo));
    _mm256_add_epi32(acc, _mm256_madd_epi16(x_hi, y_hi))
}

/// Nibble x nibble dot over packed byte streams (`nb` bytes = `2 * nb`
/// values): lo/hi nibbles sign-extend to i8 lanes in-register, then
/// widen-MAC like the i8 path.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn dot_nib_avx2(a: &[u8], w: &[u8]) -> i32 {
    let nb = a.len();
    let lo_mask = _mm256_set1_epi8(0x0F);
    let bias = _mm256_set1_epi8(0x08);
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 32 <= nb {
        let ba = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let bw = _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i);
        let (la, ha) = nib_lanes(ba, lo_mask, bias);
        let (lw, hw) = nib_lanes(bw, lo_mask, bias);
        // lo_a[j] pairs with lo_w[j] (value 2j), hi with hi (2j + 1).
        acc = mac_i8x32(acc, la, lw);
        acc = mac_i8x32(acc, ha, hw);
        i += 32;
    }
    let mut sum = hsum_epi32(acc);
    while i < nb {
        sum += nib_lo(a[i]) as i32 * nib_lo(w[i]) as i32
            + nib_hi(a[i]) as i32 * nib_hi(w[i]) as i32;
        i += 1;
    }
    sum
}

/// Widen one 16-element i8 load to 16 i16 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn load_i8x16(s: &[i8], i: usize) -> __m256i {
    _mm256_cvtepi8_epi16(_mm_loadu_si128(s.as_ptr().add(i) as *const __m128i))
}

/// Register-blocked i8 dot: one activation stream against four weight
/// streams, four accumulator vectors live.
#[target_feature(enable = "avx2")]
unsafe fn dot4_i8_avx2(a: &[i8], ws: [&[i8]; 4]) -> [i32; 4] {
    let n = a.len();
    let mut acc = [_mm256_setzero_si256(); 4];
    let mut i = 0;
    while i + 16 <= n {
        let va = load_i8x16(a, i);
        for (q, w) in ws.iter().enumerate() {
            acc[q] = _mm256_add_epi32(acc[q], _mm256_madd_epi16(va, load_i8x16(w, i)));
        }
        i += 16;
    }
    let mut out = [0i32; 4];
    for (o, acc) in out.iter_mut().zip(acc) {
        *o = hsum_epi32(acc);
    }
    while i < n {
        for (o, w) in out.iter_mut().zip(&ws) {
            *o += a[i] as i32 * w[i] as i32;
        }
        i += 1;
    }
    out
}

/// Sign-extend the low/high nibbles of a byte vector to i8 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn nib_lanes(b: __m256i, lo_mask: __m256i, bias: __m256i) -> (__m256i, __m256i) {
    let lo = _mm256_sub_epi8(_mm256_xor_si256(_mm256_and_si256(b, lo_mask), bias), bias);
    let hi = _mm256_sub_epi8(
        _mm256_xor_si256(_mm256_and_si256(_mm256_srli_epi16::<4>(b), lo_mask), bias),
        bias,
    );
    (lo, hi)
}

/// Register-blocked nibble dot: the activation nibbles extract once
/// per step against four packed weight streams.
#[target_feature(enable = "avx2")]
unsafe fn dot4_nib_avx2(a: &[u8], ws: [&[u8]; 4]) -> [i32; 4] {
    let nb = a.len();
    let lo_mask = _mm256_set1_epi8(0x0F);
    let bias = _mm256_set1_epi8(0x08);
    let mut acc = [_mm256_setzero_si256(); 4];
    let mut i = 0;
    while i + 32 <= nb {
        let ba = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let (la, ha) = nib_lanes(ba, lo_mask, bias);
        for (q, w) in ws.iter().enumerate() {
            let bw = _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i);
            let (lw, hw) = nib_lanes(bw, lo_mask, bias);
            acc[q] = mac_i8x32(acc[q], la, lw);
            acc[q] = mac_i8x32(acc[q], ha, hw);
        }
        i += 32;
    }
    let mut out = [0i32; 4];
    for (o, acc) in out.iter_mut().zip(acc) {
        *o = hsum_epi32(acc);
    }
    while i < nb {
        for (o, w) in out.iter_mut().zip(&ws) {
            *o += nib_lo(a[i]) as i32 * nib_lo(w[i]) as i32
                + nib_hi(a[i]) as i32 * nib_hi(w[i]) as i32;
        }
        i += 1;
    }
    out
}

enum Avx2Dot<'a> {
    I8I8(&'a [i8], &'a [i8]),
    NibNib(&'a [u8], &'a [u8]),
}

impl BlockDot for Avx2Dot<'_> {
    #[inline]
    fn dot(&self, a_off: usize, w_off: usize, len: usize) -> i64 {
        // Safety: `Avx2Kernel::run_band` verified AVX2 support before
        // building this dispatcher.
        match self {
            Avx2Dot::I8I8(a, w) => unsafe {
                dot_i8_avx2(&a[a_off..a_off + len], &w[w_off..w_off + len]) as i64
            },
            Avx2Dot::NibNib(a, w) => unsafe {
                dot_nib_avx2(&a[a_off / 2..(a_off + len) / 2], &w[w_off / 2..(w_off + len) / 2])
                    as i64
            },
        }
    }

    /// Register-blocked form: the widened activation vector loads once
    /// per step and MACs against four weight streams.
    #[inline]
    fn dot4(&self, a_off: usize, w_offs: [usize; 4], len: usize) -> [i64; 4] {
        let [o0, o1, o2, o3] = w_offs;
        // Safety: see `dot` — AVX2 support was verified at dispatch.
        let out = match self {
            Avx2Dot::I8I8(a, w) => unsafe {
                dot4_i8_avx2(
                    &a[a_off..a_off + len],
                    [
                        &w[o0..o0 + len],
                        &w[o1..o1 + len],
                        &w[o2..o2 + len],
                        &w[o3..o3 + len],
                    ],
                )
            },
            Avx2Dot::NibNib(a, w) => unsafe {
                dot4_nib_avx2(
                    &a[a_off / 2..(a_off + len) / 2],
                    [
                        &w[o0 / 2..(o0 + len) / 2],
                        &w[o1 / 2..(o1 + len) / 2],
                        &w[o2 / 2..(o2 + len) / 2],
                        &w[o3 / 2..(o3 + len) / 2],
                    ],
                )
            },
        };
        [out[0] as i64, out[1] as i64, out[2] as i64, out[3] as i64]
    }
}

impl GemmKernel for Avx2Kernel {
    fn name(&self) -> &'static str {
        "avx2-widening"
    }

    /// Support includes the runtime feature check (cheap — std caches
    /// detection) and the i32-accumulator block bound, so a forced
    /// `Avx2Kernel` on a host without AVX2 — or on oversized blocks —
    /// degrades down the registry's fallback chain like any other
    /// unsupported combination: the "never panics" contract of
    /// [`crate::bfp::gemm::gemm_packed_with`] holds everywhere, and
    /// the kernel name reported in stats is the backend that ran.
    fn supports(&self, x: PlaneLayout, w: PlaneLayout, block: usize) -> bool {
        block <= MAX_I32_BLOCK
            && std::arch::is_x86_feature_detected!("avx2")
            && matches!(
                (x, w),
                (PlaneLayout::I8, PlaneLayout::I8)
                    | (PlaneLayout::I4Packed, PlaneLayout::I4Packed)
            )
    }

    fn run_band(&self, t: BandTask<'_>) {
        if !std::arch::is_x86_feature_detected!("avx2")
            || t.x.fmt.block_size > MAX_I32_BLOCK
            || t.w.fmt.block_size > MAX_I32_BLOCK
        {
            // Oversized blocks need i64 accumulation; a missing-AVX2
            // dispatch can only be reached by calling the kernel
            // directly (the registry and `supports` both gate on
            // detection) — either way, stay correct via the reference.
            return super::ScalarTiledKernel.run_band(t);
        }
        let BandTask {
            x,
            w,
            xsh,
            wsh,
            r0,
            rows,
            out,
        } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => Avx2Dot::I8I8(a, wm),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => Avx2Dot::NibNib(a, wm),
            _ => {
                debug_assert!(false, "AVX2 kernel dispatched an unsupported plane pair");
                return super::ScalarTiledKernel.run_band(BandTask {
                    x,
                    w,
                    xsh,
                    wsh,
                    r0,
                    rows,
                    out,
                });
            }
        };
        run_tiled_band(&d, xsh, wsh, r0, rows, n, kb, b, out)
    }

    fn run_band_macs(&self, t: MacBandTask<'_>) {
        if !std::arch::is_x86_feature_detected!("avx2")
            || t.x.fmt.block_size > MAX_I32_BLOCK
            || t.w.fmt.block_size > MAX_I32_BLOCK
        {
            // Same re-check as `run_band`: direct callers stay correct
            // via the portable generic loop.
            return run_band_macs_generic(t);
        }
        let MacBandTask { x, w, r0, rows, macs } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => Avx2Dot::I8I8(a, wm),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => Avx2Dot::NibNib(a, wm),
            _ => {
                debug_assert!(false, "AVX2 MAC pass dispatched an unsupported plane pair");
                return run_band_macs_generic(MacBandTask { x, w, r0, rows, macs });
            }
        };
        run_tiled_band_macs(&d, r0, rows, n, kb, b, macs)
    }
}
