//! The portable reference backend: exact integer block dots under the
//! shared tiled band loop. Runs **every** layout pair (nibble-packed,
//! i8, i16, and all mixed-width combinations), which makes it the
//! guaranteed tail of the registry's fallback chain and the kernel
//! every other backend is property-tested against.
//!
//! Byte/i16 plane pairs keep the PR-1 zipped-subslice inner loops
//! ([`SliceDot`] — the shape LLVM autovectorizes and the baseline the
//! per-kernel bench series compares against); only nibble-involved
//! pairs go through the index-generic [`AccessDot`].

use super::{
    run_tiled_band, with_plane_pair_dot, BandTask, BlockDot, GemmKernel, PlaneAccess,
    MAX_I32_BLOCK,
};
use crate::bfp::packed::{Mantissa, PlaneLayout};

/// The portable cache-tiled, register-blocked kernel (see module docs).
pub struct ScalarTiledKernel;

/// Zipped-subslice block dot over two [`Mantissa`] planes — the
/// original PR-1 inner loops, unchanged: sub-slice once per block,
/// iterate zipped, accumulate in i32 when both sides are narrow and
/// the block MAC provably fits, i64 otherwise. Shared with
/// [`crate::bfp::gemm::packed_dot`], which dispatches its byte/i16
/// pairs here for the same autovectorization reason.
pub(crate) struct SliceDot<'a, A, B> {
    pub(crate) a: &'a [A],
    pub(crate) w: &'a [B],
}

impl<A: Mantissa, B: Mantissa> BlockDot for SliceDot<'_, A, B> {
    #[inline]
    fn dot(&self, a_off: usize, w_off: usize, len: usize) -> i64 {
        let a = &self.a[a_off..a_off + len];
        let w = &self.w[w_off..w_off + len];
        if A::NARROW && B::NARROW && len <= MAX_I32_BLOCK {
            let mut acc = 0i32;
            for (&x, &y) in a.iter().zip(w) {
                acc += x.widen() * y.widen();
            }
            acc as i64
        } else {
            let mut acc = 0i64;
            for (&x, &y) in a.iter().zip(w) {
                acc += x.widen() as i64 * y.widen() as i64;
            }
            acc
        }
    }

    #[inline]
    fn dot4(&self, a_off: usize, w_offs: [usize; 4], len: usize) -> [i64; 4] {
        let a = &self.a[a_off..a_off + len];
        let [o0, o1, o2, o3] = w_offs;
        let w0 = &self.w[o0..o0 + len];
        let w1 = &self.w[o1..o1 + len];
        let w2 = &self.w[o2..o2 + len];
        let w3 = &self.w[o3..o3 + len];
        if A::NARROW && B::NARROW && len <= MAX_I32_BLOCK {
            let (mut c0, mut c1, mut c2, mut c3) = (0i32, 0i32, 0i32, 0i32);
            for i in 0..len {
                let x = a[i].widen();
                c0 += x * w0[i].widen();
                c1 += x * w1[i].widen();
                c2 += x * w2[i].widen();
                c3 += x * w3[i].widen();
            }
            [c0 as i64, c1 as i64, c2 as i64, c3 as i64]
        } else {
            let (mut c0, mut c1, mut c2, mut c3) = (0i64, 0i64, 0i64, 0i64);
            for i in 0..len {
                let x = a[i].widen() as i64;
                c0 += x * w0[i].widen() as i64;
                c1 += x * w1[i].widen() as i64;
                c2 += x * w2[i].widen() as i64;
                c3 += x * w3[i].widen() as i64;
            }
            [c0, c1, c2, c3]
        }
    }
}

/// Layout-generic block dot: indexes both planes through
/// [`PlaneAccess`], accumulating in i32 when both sides are narrow and
/// the block MAC provably fits, i64 otherwise — the exact arithmetic
/// of the original scalar kernel.
pub(crate) struct AccessDot<A, B> {
    pub(crate) a: A,
    pub(crate) w: B,
}

impl<A: PlaneAccess, B: PlaneAccess> BlockDot for AccessDot<A, B> {
    #[inline]
    fn dot(&self, a_off: usize, w_off: usize, len: usize) -> i64 {
        if A::NARROW && B::NARROW && len <= MAX_I32_BLOCK {
            let mut acc = 0i32;
            for i in 0..len {
                acc += self.a.get(a_off + i) * self.w.get(w_off + i);
            }
            acc as i64
        } else {
            let mut acc = 0i64;
            for i in 0..len {
                acc += self.a.get(a_off + i) as i64 * self.w.get(w_off + i) as i64;
            }
            acc
        }
    }

    #[inline]
    fn dot4(&self, a_off: usize, w_offs: [usize; 4], len: usize) -> [i64; 4] {
        let [o0, o1, o2, o3] = w_offs;
        if A::NARROW && B::NARROW && len <= MAX_I32_BLOCK {
            let (mut c0, mut c1, mut c2, mut c3) = (0i32, 0i32, 0i32, 0i32);
            for i in 0..len {
                let x = self.a.get(a_off + i);
                c0 += x * self.w.get(o0 + i);
                c1 += x * self.w.get(o1 + i);
                c2 += x * self.w.get(o2 + i);
                c3 += x * self.w.get(o3 + i);
            }
            [c0 as i64, c1 as i64, c2 as i64, c3 as i64]
        } else {
            let (mut c0, mut c1, mut c2, mut c3) = (0i64, 0i64, 0i64, 0i64);
            for i in 0..len {
                let x = self.a.get(a_off + i) as i64;
                c0 += x * self.w.get(o0 + i) as i64;
                c1 += x * self.w.get(o1 + i) as i64;
                c2 += x * self.w.get(o2 + i) as i64;
                c3 += x * self.w.get(o3 + i) as i64;
            }
            [c0, c1, c2, c3]
        }
    }
}

impl GemmKernel for ScalarTiledKernel {
    fn name(&self) -> &'static str {
        "scalar-tiled"
    }

    fn supports(&self, _x: PlaneLayout, _w: PlaneLayout, _block: usize) -> bool {
        true
    }

    fn run_band(&self, t: BandTask<'_>) {
        let BandTask {
            x,
            w,
            xsh,
            wsh,
            r0,
            rows,
            out,
        } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        // Plane-view construction is single-homed in the shared macro;
        // this kernel contributes only the traversal call.
        with_plane_pair_dot!(&x.mantissas, &w.mantissas, |d| run_tiled_band(
            &d, xsh, wsh, r0, rows, n, kb, b, out
        ))
    }
}
