//! Explicit AVX-512 backend (x86_64 only): 512-bit widening integer
//! MACs over narrow planes, using VNNI's fused `vpdpwssd` where the
//! host has it and `vpmaddwd`+`vpaddd` otherwise.
//!
//! Registered by the kernel registry only when
//! `is_x86_feature_detected!("avx512f")` and `("avx512bw")` hold;
//! `run_band` re-checks and falls back to the scalar kernel (loudly,
//! in debug builds) if it is ever dispatched on a host without them,
//! so the unsafe `#[target_feature]` calls below are never reached
//! undetected. The VNNI path is a second runtime gate inside the
//! kernel: `avx512vnni` swaps the two-instruction widen-MAC for the
//! fused `_mm512_dpwssd_epi32` — both compute the identical exact
//! integer sum, so the gate never changes results, only throughput.
//!
//! # Exactness = bit-identity
//!
//! Identical argument to the AVX2 backend, with wider vectors: i8 (or
//! sign-extended nibble) values widen to i16 lanes; `vpmaddwd` /
//! `vpdpwssd` pair-products fit i32 lanes, and for blocks up to
//! [`MAX_I32_BLOCK`] the per-lane accumulators provably cannot wrap
//! (`2^11` steps x `2^15` per pair-sum < `2^27`). Integer addition is
//! associative, so lane-parallel sums equal the scalar kernel's
//! sequential sums bit-for-bit once combined; the shared tiled band
//! loop fixes the f64 combination order. Oversized blocks (which need
//! i64 accumulation) delegate to the scalar kernel.
//!
//! Nibble-packed operands are consumed directly from the byte stream:
//! 32 packed bytes (64 values) per step, sign-extended in 256-bit
//! registers via `((b & 0xF) ^ 8) - 8` and widened to two 512-bit
//! i16 vectors — no unpack buffer.

use super::{
    run_band_macs_generic, run_tiled_band, run_tiled_band_macs, BandTask, BlockDot, GemmKernel,
    MacBandTask, MAX_I32_BLOCK,
};
use crate::bfp::packed::{nib_hi, nib_lo, MantissaPlane, PlaneLayout};
use std::arch::x86_64::*;

/// The runtime-detected AVX-512/VNNI kernel (see module docs).
pub struct Avx512Kernel;

/// Both 512-bit base features the kernel needs: `avx512f` for the
/// vector arithmetic, `avx512bw` for byte/word widening.
pub(crate) fn avx512_available() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx512bw")
}

/// Horizontal sum of sixteen i32 lanes.
#[inline]
#[target_feature(enable = "avx512f")]
unsafe fn hsum_epi32_512(v: __m512i) -> i32 {
    let s = _mm256_add_epi32(_mm512_castsi512_si256(v), _mm512_extracti64x4_epi64::<1>(v));
    let s = _mm_add_epi32(_mm256_castsi256_si128(s), _mm256_extracti128_si256::<1>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01_00_11_10>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b10_11_00_01>(s));
    _mm_cvtsi128_si32(s)
}

/// Widen one 32-element i8 load to 32 i16 lanes.
#[inline]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn load_i8x32(s: &[i8], i: usize) -> __m512i {
    _mm512_cvtepi8_epi16(_mm256_loadu_si256(s.as_ptr().add(i) as *const __m256i))
}

/// Sign-extend the low/high nibbles of 32 packed bytes and widen each
/// set to 32 i16 lanes.
#[inline]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn nib_lanes_512(b: __m256i, lo_mask: __m256i, bias: __m256i) -> (__m512i, __m512i) {
    let lo = _mm256_sub_epi8(_mm256_xor_si256(_mm256_and_si256(b, lo_mask), bias), bias);
    let hi = _mm256_sub_epi8(
        _mm256_xor_si256(_mm256_and_si256(_mm256_srli_epi16::<4>(b), lo_mask), bias),
        bias,
    );
    (_mm512_cvtepi8_epi16(lo), _mm512_cvtepi8_epi16(hi))
}

/// Two-instruction widen-MAC: pair-products into i32 lanes, then add.
#[inline]
#[target_feature(enable = "avx512f,avx512bw")]
unsafe fn mac_madd(acc: __m512i, x: __m512i, y: __m512i) -> __m512i {
    _mm512_add_epi32(acc, _mm512_madd_epi16(x, y))
}

/// VNNI fused widen-MAC — same exact i32 result as [`mac_madd`].
#[inline]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
unsafe fn mac_vnni(acc: __m512i, x: __m512i, y: __m512i) -> __m512i {
    _mm512_dpwssd_epi32(acc, x, y)
}

/// Generate the four inner-dot entry points for one MAC flavor. The
/// madd and VNNI instantiations are bit-identical by construction;
/// only the instruction sequence differs.
macro_rules! define_avx512_dots {
    ($feat:literal, $mac:ident, $dot_i8:ident, $dot4_i8:ident, $dot_nib:ident,
     $dot4_nib:ident) => {
        #[target_feature(enable = $feat)]
        unsafe fn $dot_i8(a: &[i8], w: &[i8]) -> i32 {
            let n = a.len();
            let mut acc = _mm512_setzero_si512();
            let mut i = 0;
            while i + 32 <= n {
                acc = $mac(acc, load_i8x32(a, i), load_i8x32(w, i));
                i += 32;
            }
            let mut sum = hsum_epi32_512(acc);
            while i < n {
                sum += a[i] as i32 * w[i] as i32;
                i += 1;
            }
            sum
        }

        /// Register-blocked form: one activation stream against four
        /// weight streams, four accumulator vectors live.
        #[target_feature(enable = $feat)]
        unsafe fn $dot4_i8(a: &[i8], ws: [&[i8]; 4]) -> [i32; 4] {
            let n = a.len();
            let mut acc = [_mm512_setzero_si512(); 4];
            let mut i = 0;
            while i + 32 <= n {
                let va = load_i8x32(a, i);
                for (q, w) in ws.iter().enumerate() {
                    acc[q] = $mac(acc[q], va, load_i8x32(w, i));
                }
                i += 32;
            }
            let mut out = [0i32; 4];
            for (o, acc) in out.iter_mut().zip(acc) {
                *o = hsum_epi32_512(acc);
            }
            while i < n {
                for (o, w) in out.iter_mut().zip(&ws) {
                    *o += a[i] as i32 * w[i] as i32;
                }
                i += 1;
            }
            out
        }

        /// Nibble x nibble dot over packed byte streams (`nb` bytes =
        /// `2 * nb` values): lo nibbles pair with lo (value `2j`), hi
        /// with hi (`2j + 1`).
        #[target_feature(enable = $feat)]
        unsafe fn $dot_nib(a: &[u8], w: &[u8]) -> i32 {
            let nb = a.len();
            let lo_mask = _mm256_set1_epi8(0x0F);
            let bias = _mm256_set1_epi8(0x08);
            let mut acc = _mm512_setzero_si512();
            let mut i = 0;
            while i + 32 <= nb {
                let ba = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
                let bw = _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i);
                let (la, ha) = nib_lanes_512(ba, lo_mask, bias);
                let (lw, hw) = nib_lanes_512(bw, lo_mask, bias);
                acc = $mac(acc, la, lw);
                acc = $mac(acc, ha, hw);
                i += 32;
            }
            let mut sum = hsum_epi32_512(acc);
            while i < nb {
                sum += nib_lo(a[i]) as i32 * nib_lo(w[i]) as i32
                    + nib_hi(a[i]) as i32 * nib_hi(w[i]) as i32;
                i += 1;
            }
            sum
        }

        /// Register-blocked nibble dot: activation nibbles extract once
        /// per step against four packed weight streams.
        #[target_feature(enable = $feat)]
        unsafe fn $dot4_nib(a: &[u8], ws: [&[u8]; 4]) -> [i32; 4] {
            let nb = a.len();
            let lo_mask = _mm256_set1_epi8(0x0F);
            let bias = _mm256_set1_epi8(0x08);
            let mut acc = [_mm512_setzero_si512(); 4];
            let mut i = 0;
            while i + 32 <= nb {
                let ba = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
                let (la, ha) = nib_lanes_512(ba, lo_mask, bias);
                for (q, w) in ws.iter().enumerate() {
                    let bw = _mm256_loadu_si256(w.as_ptr().add(i) as *const __m256i);
                    let (lw, hw) = nib_lanes_512(bw, lo_mask, bias);
                    acc[q] = $mac(acc[q], la, lw);
                    acc[q] = $mac(acc[q], ha, hw);
                }
                i += 32;
            }
            let mut out = [0i32; 4];
            for (o, acc) in out.iter_mut().zip(acc) {
                *o = hsum_epi32_512(acc);
            }
            while i < nb {
                for (o, w) in out.iter_mut().zip(&ws) {
                    *o += nib_lo(a[i]) as i32 * nib_lo(w[i]) as i32
                        + nib_hi(a[i]) as i32 * nib_hi(w[i]) as i32;
                }
                i += 1;
            }
            out
        }
    };
}

define_avx512_dots!(
    "avx512f,avx512bw",
    mac_madd,
    dot_i8_madd,
    dot4_i8_madd,
    dot_nib_madd,
    dot4_nib_madd
);
define_avx512_dots!(
    "avx512f,avx512bw,avx512vnni",
    mac_vnni,
    dot_i8_vnni,
    dot4_i8_vnni,
    dot_nib_vnni,
    dot4_nib_vnni
);

/// Plane-pair dispatcher; the `vnni` flag is sampled once per band.
enum Avx512Dot<'a> {
    I8I8(&'a [i8], &'a [i8], bool),
    NibNib(&'a [u8], &'a [u8], bool),
}

impl BlockDot for Avx512Dot<'_> {
    #[inline]
    fn dot(&self, a_off: usize, w_off: usize, len: usize) -> i64 {
        // Safety: `Avx512Kernel::run_band` verified avx512f/bw support
        // (and the VNNI flag) before building this dispatcher.
        match self {
            Avx512Dot::I8I8(a, w, vnni) => unsafe {
                let (a, w) = (&a[a_off..a_off + len], &w[w_off..w_off + len]);
                if *vnni {
                    dot_i8_vnni(a, w) as i64
                } else {
                    dot_i8_madd(a, w) as i64
                }
            },
            Avx512Dot::NibNib(a, w, vnni) => unsafe {
                let (a, w) = (&a[a_off / 2..(a_off + len) / 2], &w[w_off / 2..(w_off + len) / 2]);
                if *vnni {
                    dot_nib_vnni(a, w) as i64
                } else {
                    dot_nib_madd(a, w) as i64
                }
            },
        }
    }

    /// Register-blocked form: the widened activation vector loads once
    /// per step and MACs against four weight streams.
    #[inline]
    fn dot4(&self, a_off: usize, w_offs: [usize; 4], len: usize) -> [i64; 4] {
        let [o0, o1, o2, o3] = w_offs;
        // Safety: see `dot` — features were verified at dispatch.
        let out = match self {
            Avx512Dot::I8I8(a, w, vnni) => unsafe {
                let a = &a[a_off..a_off + len];
                let ws = [
                    &w[o0..o0 + len],
                    &w[o1..o1 + len],
                    &w[o2..o2 + len],
                    &w[o3..o3 + len],
                ];
                if *vnni {
                    dot4_i8_vnni(a, ws)
                } else {
                    dot4_i8_madd(a, ws)
                }
            },
            Avx512Dot::NibNib(a, w, vnni) => unsafe {
                let a = &a[a_off / 2..(a_off + len) / 2];
                let ws = [
                    &w[o0 / 2..(o0 + len) / 2],
                    &w[o1 / 2..(o1 + len) / 2],
                    &w[o2 / 2..(o2 + len) / 2],
                    &w[o3 / 2..(o3 + len) / 2],
                ];
                if *vnni {
                    dot4_nib_vnni(a, ws)
                } else {
                    dot4_nib_madd(a, ws)
                }
            },
        };
        [out[0] as i64, out[1] as i64, out[2] as i64, out[3] as i64]
    }
}

impl GemmKernel for Avx512Kernel {
    fn name(&self) -> &'static str {
        "avx512-vnni"
    }

    /// Support includes the runtime feature check (cheap — std caches
    /// detection) and the i32-accumulator block bound, so a forced
    /// `Avx512Kernel` on a host without AVX-512 — or on oversized
    /// blocks — degrades down the registry's fallback chain like any
    /// other unsupported combination.
    fn supports(&self, x: PlaneLayout, w: PlaneLayout, block: usize) -> bool {
        block <= MAX_I32_BLOCK
            && avx512_available()
            && matches!(
                (x, w),
                (PlaneLayout::I8, PlaneLayout::I8)
                    | (PlaneLayout::I4Packed, PlaneLayout::I4Packed)
            )
    }

    fn run_band(&self, t: BandTask<'_>) {
        if !avx512_available()
            || t.x.fmt.block_size > MAX_I32_BLOCK
            || t.w.fmt.block_size > MAX_I32_BLOCK
        {
            // Oversized blocks need i64 accumulation; a missing-feature
            // dispatch can only be reached by calling the kernel
            // directly (the registry and `supports` both gate on
            // detection) — either way, stay correct via the reference.
            return super::ScalarTiledKernel.run_band(t);
        }
        let BandTask {
            x,
            w,
            xsh,
            wsh,
            r0,
            rows,
            out,
        } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let vnni = std::arch::is_x86_feature_detected!("avx512vnni");
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => Avx512Dot::I8I8(a, wm, vnni),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => {
                Avx512Dot::NibNib(a, wm, vnni)
            }
            _ => {
                debug_assert!(false, "AVX-512 kernel dispatched an unsupported plane pair");
                return super::ScalarTiledKernel.run_band(BandTask {
                    x,
                    w,
                    xsh,
                    wsh,
                    r0,
                    rows,
                    out,
                });
            }
        };
        run_tiled_band(&d, xsh, wsh, r0, rows, n, kb, b, out)
    }

    fn run_band_macs(&self, t: MacBandTask<'_>) {
        if !avx512_available()
            || t.x.fmt.block_size > MAX_I32_BLOCK
            || t.w.fmt.block_size > MAX_I32_BLOCK
        {
            // Same re-check as `run_band`: direct callers stay correct
            // via the portable generic loop.
            return run_band_macs_generic(t);
        }
        let MacBandTask { x, w, r0, rows, macs } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let vnni = std::arch::is_x86_feature_detected!("avx512vnni");
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => Avx512Dot::I8I8(a, wm, vnni),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => {
                Avx512Dot::NibNib(a, wm, vnni)
            }
            _ => {
                debug_assert!(false, "AVX-512 MAC pass dispatched an unsupported plane pair");
                return run_band_macs_generic(MacBandTask { x, w, r0, rows, macs });
            }
        };
        run_tiled_band_macs(&d, r0, rows, n, kb, b, macs)
    }
}
