//! Unrolled, autovectorization-friendly backend for narrow mantissa
//! planes (`i8` bytes and nibble-packed 4-bit pairs).
//!
//! The inner loops keep a fixed array of independent i32 lane
//! accumulators over exact-size chunks, the shape LLVM reliably turns
//! into SIMD on any target — no intrinsics, no feature detection.
//! Integer addition is associative, so lane-reassociated sums equal
//! the scalar kernel's sequential sums exactly; bit-identity is free.
//!
//! Nibble-packed operands are consumed **directly**: one byte yields
//! two sign-extended 4-bit mantissas inside the loop body (values
//! `2j`/`2j + 1` pair up across operands because both planes share the
//! packing order), so the 4-bit formats run at byte-stream bandwidth.

use super::{
    run_band_macs_generic, run_tiled_band, run_tiled_band_macs, BandTask, BlockDot, GemmKernel,
    MacBandTask, MAX_I32_BLOCK,
};
use crate::bfp::packed::{nib_hi, nib_lo, MantissaPlane, PlaneLayout};

/// Lane width of the unrolled accumulators. 8 i32 lanes map onto one
/// AVX2 register or two NEON registers; narrower targets just unroll.
const LANES: usize = 8;

/// The unrolled narrow-plane kernel (see module docs).
pub struct AutovecKernel;

#[inline]
fn dot_i8_unrolled(a: &[i8], w: &[i8]) -> i32 {
    let mut lanes = [0i32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cw = w.chunks_exact(LANES);
    for (xa, xw) in (&mut ca).zip(&mut cw) {
        for l in 0..LANES {
            lanes[l] += xa[l] as i32 * xw[l] as i32;
        }
    }
    let mut acc: i32 = lanes.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cw.remainder()) {
        acc += *x as i32 * *y as i32;
    }
    acc
}

/// Nibble x nibble over packed bytes: each byte pair contributes
/// `lo*lo + hi*hi` (the packing order aligns values across operands).
#[inline]
fn dot_nib_nib(a: &[u8], w: &[u8]) -> i32 {
    let mut lanes = [0i32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cw = w.chunks_exact(LANES);
    for (xa, xw) in (&mut ca).zip(&mut cw) {
        for l in 0..LANES {
            lanes[l] += nib_lo(xa[l]) as i32 * nib_lo(xw[l]) as i32
                + nib_hi(xa[l]) as i32 * nib_hi(xw[l]) as i32;
        }
    }
    let mut acc: i32 = lanes.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cw.remainder()) {
        acc += nib_lo(*x) as i32 * nib_lo(*y) as i32 + nib_hi(*x) as i32 * nib_hi(*y) as i32;
    }
    acc
}

/// Nibble x i8 (mixed mantissa widths, e.g. HBFP4 activations against
/// HBFP6 weights): byte `j` of the packed side pairs with bytes
/// `2j`/`2j + 1` of the byte plane.
#[inline]
fn dot_nib_i8(a: &[u8], w: &[i8]) -> i32 {
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    for (j, &byte) in a.iter().enumerate() {
        acc0 += nib_lo(byte) as i32 * w[2 * j] as i32;
        acc1 += nib_hi(byte) as i32 * w[2 * j + 1] as i32;
    }
    acc0 + acc1
}

/// Narrow block-dot dispatch by (sub)plane pair at absolute offsets.
/// Offsets and lengths on the nibble side are always even and
/// byte-aligned (even block sizes — see the layout contract).
enum NarrowDot<'a> {
    I8I8(&'a [i8], &'a [i8]),
    NibNib(&'a [u8], &'a [u8]),
    NibI8(&'a [u8], &'a [i8]),
    I8Nib(&'a [i8], &'a [u8]),
}

impl BlockDot for NarrowDot<'_> {
    #[inline]
    fn dot(&self, a_off: usize, w_off: usize, len: usize) -> i64 {
        match self {
            NarrowDot::I8I8(a, w) => {
                dot_i8_unrolled(&a[a_off..a_off + len], &w[w_off..w_off + len]) as i64
            }
            NarrowDot::NibNib(a, w) => {
                dot_nib_nib(&a[a_off / 2..(a_off + len) / 2], &w[w_off / 2..(w_off + len) / 2])
                    as i64
            }
            NarrowDot::NibI8(a, w) => {
                dot_nib_i8(&a[a_off / 2..(a_off + len) / 2], &w[w_off..w_off + len]) as i64
            }
            NarrowDot::I8Nib(a, w) => {
                dot_nib_i8(&w[w_off / 2..(w_off + len) / 2], &a[a_off..a_off + len]) as i64
            }
        }
    }

    /// Register-blocked form for the homogeneous pairs: the activation
    /// block streams once against four weight blocks with four live
    /// accumulators (the shape the shared band loop is built around).
    /// Mixed nibble/byte pairs (rare cross-width ops) keep four
    /// independent dots.
    #[inline]
    fn dot4(&self, a_off: usize, w_offs: [usize; 4], len: usize) -> [i64; 4] {
        let [o0, o1, o2, o3] = w_offs;
        match self {
            NarrowDot::I8I8(a, w) => {
                // Lane-unrolled x register-blocked: four i32 lanes per
                // weight stream (LLVM folds each quad into one SIMD
                // accumulator), activation chunk loaded once per step.
                // Exact integer sums, so lane reassociation keeps
                // bit-identity with the sequential reference.
                const Q: usize = 4;
                let a = &a[a_off..a_off + len];
                let mut ca = a.chunks_exact(Q);
                let mut cw = [
                    w[o0..o0 + len].chunks_exact(Q),
                    w[o1..o1 + len].chunks_exact(Q),
                    w[o2..o2 + len].chunks_exact(Q),
                    w[o3..o3 + len].chunks_exact(Q),
                ];
                let mut lanes = [[0i32; Q]; 4];
                for xa in &mut ca {
                    for (q, cwq) in cw.iter_mut().enumerate() {
                        let xw = cwq.next().expect("weight blocks match block length");
                        for l in 0..Q {
                            lanes[q][l] += xa[l] as i32 * xw[l] as i32;
                        }
                    }
                }
                let mut out = [0i32; 4];
                for (o, qlanes) in out.iter_mut().zip(&lanes) {
                    *o = qlanes.iter().sum();
                }
                for (i, &x) in ca.remainder().iter().enumerate() {
                    for (o, cwq) in out.iter_mut().zip(&cw) {
                        *o += x as i32 * cwq.remainder()[i] as i32;
                    }
                }
                let [c0, c1, c2, c3] = out;
                [c0 as i64, c1 as i64, c2 as i64, c3 as i64]
            }
            NarrowDot::NibNib(a, w) => {
                let ab = &a[a_off / 2..(a_off + len) / 2];
                let w0 = &w[o0 / 2..(o0 + len) / 2];
                let w1 = &w[o1 / 2..(o1 + len) / 2];
                let w2 = &w[o2 / 2..(o2 + len) / 2];
                let w3 = &w[o3 / 2..(o3 + len) / 2];
                let (mut c0, mut c1, mut c2, mut c3) = (0i32, 0i32, 0i32, 0i32);
                for i in 0..ab.len() {
                    let (lo, hi) = (nib_lo(ab[i]) as i32, nib_hi(ab[i]) as i32);
                    c0 += lo * nib_lo(w0[i]) as i32 + hi * nib_hi(w0[i]) as i32;
                    c1 += lo * nib_lo(w1[i]) as i32 + hi * nib_hi(w1[i]) as i32;
                    c2 += lo * nib_lo(w2[i]) as i32 + hi * nib_hi(w2[i]) as i32;
                    c3 += lo * nib_lo(w3[i]) as i32 + hi * nib_hi(w3[i]) as i32;
                }
                [c0 as i64, c1 as i64, c2 as i64, c3 as i64]
            }
            _ => [
                self.dot(a_off, o0, len),
                self.dot(a_off, o1, len),
                self.dot(a_off, o2, len),
                self.dot(a_off, o3, len),
            ],
        }
    }
}

impl GemmKernel for AutovecKernel {
    fn name(&self) -> &'static str {
        "autovec"
    }

    /// Narrow planes only, and only blocks whose MAC fits the i32 lane
    /// accumulators — the registry keeps wide planes and oversized
    /// blocks on the scalar kernel, so the reported kernel identity is
    /// the backend that actually ran.
    fn supports(&self, x: PlaneLayout, w: PlaneLayout, block: usize) -> bool {
        block <= MAX_I32_BLOCK
            && matches!(x, PlaneLayout::I4Packed | PlaneLayout::I8)
            && matches!(w, PlaneLayout::I4Packed | PlaneLayout::I8)
    }

    fn run_band(&self, t: BandTask<'_>) {
        if t.x.fmt.block_size > MAX_I32_BLOCK || t.w.fmt.block_size > MAX_I32_BLOCK {
            // Unreachable via the registry (`supports` gates on block
            // size); direct callers stay correct via the reference.
            return super::ScalarTiledKernel.run_band(t);
        }
        let BandTask {
            x,
            w,
            xsh,
            wsh,
            r0,
            rows,
            out,
        } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => NarrowDot::I8I8(a, wm),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => NarrowDot::NibNib(a, wm),
            (MantissaPlane::I4Packed(a), MantissaPlane::I8(wm)) => NarrowDot::NibI8(a, wm),
            (MantissaPlane::I8(a), MantissaPlane::I4Packed(wm)) => NarrowDot::I8Nib(a, wm),
            _ => {
                // Unsupported pair dispatched here by mistake: stay
                // correct anyway via the reference kernel.
                debug_assert!(false, "autovec kernel dispatched a wide plane");
                return super::ScalarTiledKernel.run_band(BandTask {
                    x,
                    w,
                    xsh,
                    wsh,
                    r0,
                    rows,
                    out,
                });
            }
        };
        run_tiled_band(&d, xsh, wsh, r0, rows, n, kb, b, out)
    }

    fn run_band_macs(&self, t: MacBandTask<'_>) {
        if t.x.fmt.block_size > MAX_I32_BLOCK || t.w.fmt.block_size > MAX_I32_BLOCK {
            // Callers gate the split on `mac_split_supported`, but stay
            // correct for direct callers via the generic loop.
            return run_band_macs_generic(t);
        }
        let MacBandTask { x, w, r0, rows, macs } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => NarrowDot::I8I8(a, wm),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => NarrowDot::NibNib(a, wm),
            (MantissaPlane::I4Packed(a), MantissaPlane::I8(wm)) => NarrowDot::NibI8(a, wm),
            (MantissaPlane::I8(a), MantissaPlane::I4Packed(wm)) => NarrowDot::I8Nib(a, wm),
            _ => {
                debug_assert!(false, "autovec MAC pass dispatched a wide plane");
                return run_band_macs_generic(MacBandTask { x, w, r0, rows, macs });
            }
        };
        run_tiled_band_macs(&d, r0, rows, n, kb, b, macs)
    }
}
