//! Explicit NEON backend (aarch64 only): widening integer MACs over
//! narrow planes via `smull`/`sadalp` lanes, upgraded to the fused
//! `sdot` (`vdotq_s32`) where the host reports the `dotprod` feature.
//!
//! Registered by the kernel registry only on aarch64 hosts (NEON is
//! architecturally baseline there, but we keep the runtime check for
//! symmetry with the x86 backends); `run_band` re-checks and falls
//! back to the scalar kernel (loudly, in debug builds) if it is ever
//! dispatched without support. The `dotprod` path is a second runtime
//! gate inside the kernel — both MAC flavors compute the identical
//! exact integer sum, so the gate never changes results, only
//! throughput.
//!
//! # Exactness = bit-identity
//!
//! Same argument as the x86 SIMD backends: i8 (or sign-extended
//! nibble) products fit i16 (`smull`), pairwise-accumulate into i32
//! lanes (`sadalp`), and for blocks up to [`MAX_I32_BLOCK`] the
//! per-lane accumulators provably cannot wrap (`2^12` steps x `2^16`
//! per step < `2^29`); `sdot` accumulates 4-element i8 dot products
//! into i32 lanes with the same bound. Integer addition is
//! associative, so lane-parallel sums equal the scalar kernel's
//! sequential sums bit-for-bit once combined; the shared tiled band
//! loop fixes the f64 combination order. Oversized blocks (which need
//! i64 accumulation) delegate to the scalar kernel.
//!
//! Nibble-packed operands are consumed directly from the byte stream:
//! 16 packed bytes (32 values) per step, sign-extended in-register via
//! `((b & 0xF) ^ 8) - 8` — no unpack buffer.

use super::{
    run_band_macs_generic, run_tiled_band, run_tiled_band_macs, BandTask, BlockDot, GemmKernel,
    MacBandTask, MAX_I32_BLOCK,
};
use crate::bfp::packed::{nib_hi, nib_lo, MantissaPlane, PlaneLayout};
use std::arch::aarch64::*;

/// The runtime-detected NEON kernel (see module docs).
pub struct NeonKernel;

pub(crate) fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// Sign-extend the low/high nibbles of 16 packed bytes to i8 lanes.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn nib_lanes_neon(b: uint8x16_t) -> (int8x16_t, int8x16_t) {
    let lo_mask = vdupq_n_u8(0x0F);
    let bias_u = vdupq_n_u8(0x08);
    let bias_s = vdupq_n_s8(0x08);
    let lo = vsubq_s8(
        vreinterpretq_s8_u8(veorq_u8(vandq_u8(b, lo_mask), bias_u)),
        bias_s,
    );
    let hi = vsubq_s8(
        vreinterpretq_s8_u8(veorq_u8(vshrq_n_u8::<4>(b), bias_u)),
        bias_s,
    );
    (lo, hi)
}

/// Widening MAC via `smull` + `sadalp`: 16 i8 products to two i16
/// vectors, pairwise-accumulated into the i32 lanes.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn mac_smull(acc: int32x4_t, x: int8x16_t, y: int8x16_t) -> int32x4_t {
    let lo = vmull_s8(vget_low_s8(x), vget_low_s8(y));
    let hi = vmull_s8(vget_high_s8(x), vget_high_s8(y));
    vpadalq_s16(vpadalq_s16(acc, lo), hi)
}

/// Fused `sdot` MAC — same exact i32 result as [`mac_smull`].
#[inline]
#[target_feature(enable = "neon,dotprod")]
unsafe fn mac_sdot(acc: int32x4_t, x: int8x16_t, y: int8x16_t) -> int32x4_t {
    vdotq_s32(acc, x, y)
}

/// Generate the four inner-dot entry points for one MAC flavor. The
/// smull and sdot instantiations are bit-identical by construction;
/// only the instruction sequence differs.
macro_rules! define_neon_dots {
    ($feat:literal, $mac:ident, $dot_i8:ident, $dot4_i8:ident, $dot_nib:ident,
     $dot4_nib:ident) => {
        #[target_feature(enable = $feat)]
        unsafe fn $dot_i8(a: &[i8], w: &[i8]) -> i32 {
            let n = a.len();
            let mut acc = vdupq_n_s32(0);
            let mut i = 0;
            while i + 16 <= n {
                acc = $mac(acc, vld1q_s8(a.as_ptr().add(i)), vld1q_s8(w.as_ptr().add(i)));
                i += 16;
            }
            let mut sum = vaddvq_s32(acc);
            while i < n {
                sum += a[i] as i32 * w[i] as i32;
                i += 1;
            }
            sum
        }

        /// Register-blocked form: one activation stream against four
        /// weight streams, four accumulator vectors live.
        #[target_feature(enable = $feat)]
        unsafe fn $dot4_i8(a: &[i8], ws: [&[i8]; 4]) -> [i32; 4] {
            let n = a.len();
            let mut acc = [vdupq_n_s32(0); 4];
            let mut i = 0;
            while i + 16 <= n {
                let va = vld1q_s8(a.as_ptr().add(i));
                for (q, w) in ws.iter().enumerate() {
                    acc[q] = $mac(acc[q], va, vld1q_s8(w.as_ptr().add(i)));
                }
                i += 16;
            }
            let mut out = [0i32; 4];
            for (o, acc) in out.iter_mut().zip(acc) {
                *o = vaddvq_s32(acc);
            }
            while i < n {
                for (o, w) in out.iter_mut().zip(&ws) {
                    *o += a[i] as i32 * w[i] as i32;
                }
                i += 1;
            }
            out
        }

        /// Nibble x nibble dot over packed byte streams (`nb` bytes =
        /// `2 * nb` values): lo nibbles pair with lo (value `2j`), hi
        /// with hi (`2j + 1`).
        #[target_feature(enable = $feat)]
        unsafe fn $dot_nib(a: &[u8], w: &[u8]) -> i32 {
            let nb = a.len();
            let mut acc = vdupq_n_s32(0);
            let mut i = 0;
            while i + 16 <= nb {
                let (la, ha) = nib_lanes_neon(vld1q_u8(a.as_ptr().add(i)));
                let (lw, hw) = nib_lanes_neon(vld1q_u8(w.as_ptr().add(i)));
                acc = $mac(acc, la, lw);
                acc = $mac(acc, ha, hw);
                i += 16;
            }
            let mut sum = vaddvq_s32(acc);
            while i < nb {
                sum += nib_lo(a[i]) as i32 * nib_lo(w[i]) as i32
                    + nib_hi(a[i]) as i32 * nib_hi(w[i]) as i32;
                i += 1;
            }
            sum
        }

        /// Register-blocked nibble dot: activation nibbles extract once
        /// per step against four packed weight streams.
        #[target_feature(enable = $feat)]
        unsafe fn $dot4_nib(a: &[u8], ws: [&[u8]; 4]) -> [i32; 4] {
            let nb = a.len();
            let mut acc = [vdupq_n_s32(0); 4];
            let mut i = 0;
            while i + 16 <= nb {
                let (la, ha) = nib_lanes_neon(vld1q_u8(a.as_ptr().add(i)));
                for (q, w) in ws.iter().enumerate() {
                    let (lw, hw) = nib_lanes_neon(vld1q_u8(w.as_ptr().add(i)));
                    acc[q] = $mac(acc[q], la, lw);
                    acc[q] = $mac(acc[q], ha, hw);
                }
                i += 16;
            }
            let mut out = [0i32; 4];
            for (o, acc) in out.iter_mut().zip(acc) {
                *o = vaddvq_s32(acc);
            }
            while i < nb {
                for (o, w) in out.iter_mut().zip(&ws) {
                    *o += nib_lo(a[i]) as i32 * nib_lo(w[i]) as i32
                        + nib_hi(a[i]) as i32 * nib_hi(w[i]) as i32;
                }
                i += 1;
            }
            out
        }
    };
}

define_neon_dots!(
    "neon",
    mac_smull,
    dot_i8_smull,
    dot4_i8_smull,
    dot_nib_smull,
    dot4_nib_smull
);
define_neon_dots!(
    "neon,dotprod",
    mac_sdot,
    dot_i8_sdot,
    dot4_i8_sdot,
    dot_nib_sdot,
    dot4_nib_sdot
);

/// Plane-pair dispatcher; the `dotprod` flag is sampled once per band.
enum NeonDot<'a> {
    I8I8(&'a [i8], &'a [i8], bool),
    NibNib(&'a [u8], &'a [u8], bool),
}

impl BlockDot for NeonDot<'_> {
    #[inline]
    fn dot(&self, a_off: usize, w_off: usize, len: usize) -> i64 {
        // Safety: `NeonKernel::run_band` verified NEON support (and the
        // dotprod flag) before building this dispatcher.
        match self {
            NeonDot::I8I8(a, w, sdot) => unsafe {
                let (a, w) = (&a[a_off..a_off + len], &w[w_off..w_off + len]);
                if *sdot {
                    dot_i8_sdot(a, w) as i64
                } else {
                    dot_i8_smull(a, w) as i64
                }
            },
            NeonDot::NibNib(a, w, sdot) => unsafe {
                let (a, w) = (&a[a_off / 2..(a_off + len) / 2], &w[w_off / 2..(w_off + len) / 2]);
                if *sdot {
                    dot_nib_sdot(a, w) as i64
                } else {
                    dot_nib_smull(a, w) as i64
                }
            },
        }
    }

    /// Register-blocked form: the activation vector loads (or its
    /// nibbles extract) once per step and MACs against four weight
    /// streams.
    #[inline]
    fn dot4(&self, a_off: usize, w_offs: [usize; 4], len: usize) -> [i64; 4] {
        let [o0, o1, o2, o3] = w_offs;
        // Safety: see `dot` — features were verified at dispatch.
        let out = match self {
            NeonDot::I8I8(a, w, sdot) => unsafe {
                let a = &a[a_off..a_off + len];
                let ws = [
                    &w[o0..o0 + len],
                    &w[o1..o1 + len],
                    &w[o2..o2 + len],
                    &w[o3..o3 + len],
                ];
                if *sdot {
                    dot4_i8_sdot(a, ws)
                } else {
                    dot4_i8_smull(a, ws)
                }
            },
            NeonDot::NibNib(a, w, sdot) => unsafe {
                let a = &a[a_off / 2..(a_off + len) / 2];
                let ws = [
                    &w[o0 / 2..(o0 + len) / 2],
                    &w[o1 / 2..(o1 + len) / 2],
                    &w[o2 / 2..(o2 + len) / 2],
                    &w[o3 / 2..(o3 + len) / 2],
                ];
                if *sdot {
                    dot4_nib_sdot(a, ws)
                } else {
                    dot4_nib_smull(a, ws)
                }
            },
        };
        [out[0] as i64, out[1] as i64, out[2] as i64, out[3] as i64]
    }
}

impl GemmKernel for NeonKernel {
    fn name(&self) -> &'static str {
        "neon-sdot"
    }

    /// Support includes the runtime feature check and the
    /// i32-accumulator block bound, so a forced `NeonKernel` on an
    /// unsupported combination degrades down the registry's fallback
    /// chain like any other backend.
    fn supports(&self, x: PlaneLayout, w: PlaneLayout, block: usize) -> bool {
        block <= MAX_I32_BLOCK
            && neon_available()
            && matches!(
                (x, w),
                (PlaneLayout::I8, PlaneLayout::I8)
                    | (PlaneLayout::I4Packed, PlaneLayout::I4Packed)
            )
    }

    fn run_band(&self, t: BandTask<'_>) {
        if !neon_available()
            || t.x.fmt.block_size > MAX_I32_BLOCK
            || t.w.fmt.block_size > MAX_I32_BLOCK
        {
            // Oversized blocks need i64 accumulation; stay correct via
            // the reference kernel in every unsupported case.
            return super::ScalarTiledKernel.run_band(t);
        }
        let BandTask {
            x,
            w,
            xsh,
            wsh,
            r0,
            rows,
            out,
        } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let sdot = std::arch::is_aarch64_feature_detected!("dotprod");
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => NeonDot::I8I8(a, wm, sdot),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => {
                NeonDot::NibNib(a, wm, sdot)
            }
            _ => {
                debug_assert!(false, "NEON kernel dispatched an unsupported plane pair");
                return super::ScalarTiledKernel.run_band(BandTask {
                    x,
                    w,
                    xsh,
                    wsh,
                    r0,
                    rows,
                    out,
                });
            }
        };
        run_tiled_band(&d, xsh, wsh, r0, rows, n, kb, b, out)
    }

    fn run_band_macs(&self, t: MacBandTask<'_>) {
        if !neon_available()
            || t.x.fmt.block_size > MAX_I32_BLOCK
            || t.w.fmt.block_size > MAX_I32_BLOCK
        {
            // Same re-check as `run_band`: direct callers stay correct
            // via the portable generic loop.
            return run_band_macs_generic(t);
        }
        let MacBandTask { x, w, r0, rows, macs } = t;
        let n = w.rows;
        let kb = x.blocks_per_row;
        let b = x.fmt.block_size;
        debug_assert_eq!(kb, w.blocks_per_row);
        let sdot = std::arch::is_aarch64_feature_detected!("dotprod");
        let d = match (&x.mantissas, &w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(wm)) => NeonDot::I8I8(a, wm, sdot),
            (MantissaPlane::I4Packed(a), MantissaPlane::I4Packed(wm)) => {
                NeonDot::NibNib(a, wm, sdot)
            }
            _ => {
                debug_assert!(false, "NEON MAC pass dispatched an unsupported plane pair");
                return run_band_macs_generic(MacBandTask { x, w, r0, rows, macs });
            }
        };
        run_tiled_band_macs(&d, r0, rows, n, kb, b, macs)
    }
}
