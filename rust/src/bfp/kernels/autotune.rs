//! Shape-aware kernel autotune table — the middle dispatch tier.
//!
//! The registry picks a backend per GEMM in three tiers (see the
//! [`super`] module docs): a forced `BOOSTERS_KERNEL` override, then
//! this table, then the static preference order. The table maps a
//! coarse problem key — operand plane-layout pair, block-size bucket,
//! and an M×N×K volume bucket — to the backend name that measured
//! fastest on this host. It is produced by the
//! `bench_quantize --autotune` pass and persisted as a JSON artifact
//! under `rust/artifacts/`.
//!
//! # JSON schema (`boosters-autotune-v1`)
//!
//! ```json
//! {
//!   "schema": "boosters-autotune-v1",
//!   "entries": [
//!     {
//!       "x": "i4x2", "w": "i4x2",
//!       "block_bucket": "b64", "mnk_bucket": "small",
//!       "kernel": "avx2-widening",
//!       "block": 64, "shape": [48, 48, 48], "mean_ns": 20480.0
//!     }
//!   ]
//! }
//! ```
//!
//! Required per entry: `x` / `w` (plane-layout labels `i4x2`, `i8`,
//! `i16`), `block_bucket` (one of [`BLOCK_BUCKETS`]), `mnk_bucket`
//! (one of [`MNK_BUCKETS`]), and `kernel` (a registry backend name).
//! `block`, `shape`, and `mean_ns` are provenance, ignored by the
//! loader. A table whose `kernel` is not registered (or does not
//! support the pair) on the loading host simply falls through to the
//! static tier at lookup time — tables are portable hints, not
//! commands. Missing/corrupt files fall back to static dispatch with
//! one warning; an absent default artifact is silent.

use std::collections::HashMap;

use crate::bfp::packed::PlaneLayout;
use crate::util::Json;

/// M×N×K volume buckets (by total MAC count `m*n*k`): `small`
/// < 2^18, `medium` < 2^24, `large` otherwise. Coarse on purpose —
/// the table stays a handful of entries and a lookup never misses
/// merely because a shape was not benchmarked exactly.
pub const MNK_BUCKETS: [&str; 3] = ["small", "medium", "large"];

/// Block-size buckets: `b16` (<= 16), `b64` (17..=128), `bwide`
/// (> 128). Wide blocks overflow i32 accumulators in the narrow SIMD
/// backends and always run scalar, so finer resolution buys nothing.
pub const BLOCK_BUCKETS: [&str; 3] = ["b16", "b64", "bwide"];

/// Index into [`MNK_BUCKETS`] for a GEMM of `m x k` by `k x n`.
pub fn mnk_bucket_index(m: usize, n: usize, k: usize) -> usize {
    let macs = (m as u64).saturating_mul(n as u64).saturating_mul(k as u64);
    if macs < 1 << 18 {
        0
    } else if macs < 1 << 24 {
        1
    } else {
        2
    }
}

/// Index into [`BLOCK_BUCKETS`] for an HBFP block size.
pub fn block_bucket_index(block: usize) -> usize {
    if block <= 16 {
        0
    } else if block <= 128 {
        1
    } else {
        2
    }
}

/// The output-shape half of a dispatch key: `m x k` activations
/// against `k x n` (pre-transposed) weights. Carried alongside the
/// operand layouts so [`super::active_kernel`] can bucket the problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        Self { m, n, k }
    }
    pub fn mnk_bucket(self) -> usize {
        mnk_bucket_index(self.m, self.n, self.k)
    }
}

type Key = (PlaneLayout, PlaneLayout, usize, usize);

fn layout_from_label(label: &str) -> Result<PlaneLayout, String> {
    match label {
        "i4x2" => Ok(PlaneLayout::I4Packed),
        "i8" => Ok(PlaneLayout::I8),
        "i16" => Ok(PlaneLayout::I16),
        other => Err(format!("unknown plane-layout label {other:?}")),
    }
}

fn bucket_from_label(label: &str, names: &[&'static str]) -> Result<usize, String> {
    names
        .iter()
        .position(|&n| n == label)
        .ok_or_else(|| format!("unknown bucket label {label:?} (expected one of {names:?})"))
}

/// A parsed autotune table: dispatch key -> preferred backend name.
#[derive(Debug, Clone, Default)]
pub struct AutotuneTable {
    entries: HashMap<Key, String>,
}

impl AutotuneTable {
    /// Parse the `boosters-autotune-v1` JSON text. Any structural
    /// problem is an error — the caller decides whether that warrants
    /// a warning (explicit `BOOSTERS_AUTOTUNE` path) or silence.
    pub fn parse(text: &str) -> Result<AutotuneTable, String> {
        let root = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
        let schema = root
            .req("schema")
            .and_then(|s| s.as_str().map(str::to_string))
            .map_err(|e| e.to_string())?;
        if schema != "boosters-autotune-v1" {
            return Err(format!("unsupported autotune schema {schema:?}"));
        }
        let raw = root
            .req("entries")
            .and_then(|e| e.as_arr().map(<[Json]>::to_vec))
            .map_err(|e| e.to_string())?;
        let mut entries = HashMap::new();
        for (i, e) in raw.iter().enumerate() {
            let field = |key: &str| -> Result<String, String> {
                e.req(key)
                    .and_then(|v| v.as_str().map(str::to_string))
                    .map_err(|err| format!("entry {i}: {err}"))
            };
            let x = layout_from_label(&field("x")?).map_err(|err| format!("entry {i}: {err}"))?;
            let w = layout_from_label(&field("w")?).map_err(|err| format!("entry {i}: {err}"))?;
            let bb = bucket_from_label(&field("block_bucket")?, &BLOCK_BUCKETS)
                .map_err(|err| format!("entry {i}: {err}"))?;
            let mb = bucket_from_label(&field("mnk_bucket")?, &MNK_BUCKETS)
                .map_err(|err| format!("entry {i}: {err}"))?;
            entries.insert((x, w, bb, mb), field("kernel")?);
        }
        Ok(AutotuneTable { entries })
    }

    /// Backend name tuned for this dispatch key, if any.
    pub fn lookup(
        &self,
        x: PlaneLayout,
        w: PlaneLayout,
        block: usize,
        shape: GemmShape,
    ) -> Option<&str> {
        self.entries
            .get(&(x, w, block_bucket_index(block), shape.mnk_bucket()))
            .map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Load the table the registry should consult, resolving
/// `BOOSTERS_AUTOTUNE` first and the default artifact paths second.
/// Every failure mode degrades to static dispatch; only an explicitly
/// named or present-but-corrupt file warns (once).
pub(crate) fn load() -> Option<AutotuneTable> {
    fn read_parse(path: &std::path::Path) -> Result<AutotuneTable, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        AutotuneTable::parse(&text)
    }
    fn warn_once(path: &std::path::Path, err: &str) {
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            eprintln!(
                "[boosters] autotune table {}: {err}; falling back to static kernel dispatch",
                path.display()
            );
        });
    }
    if let Some(path) = crate::util::autotune_path() {
        return match read_parse(&path) {
            Ok(t) => Some(t),
            Err(err) => {
                warn_once(&path, &err);
                None
            }
        };
    }
    // Probe relative to both plausible working directories: cargo runs
    // test/bench binaries from the package root (`rust/`), the repro
    // binary usually runs from the repo root.
    for cand in ["artifacts/autotune.json", "rust/artifacts/autotune.json"] {
        let path = std::path::Path::new(cand);
        if path.is_file() {
            return match read_parse(path) {
                Ok(t) => Some(t),
                Err(err) => {
                    warn_once(path, &err);
                    None
                }
            };
        }
    }
    None
}

/// Builder used by the `bench_quantize --autotune` pass: feed it one
/// timing per (key, kernel) and it keeps the fastest backend per key.
#[derive(Debug, Default)]
pub struct TableBuilder {
    best: HashMap<Key, Best>,
}

#[derive(Debug)]
struct Best {
    kernel: String,
    mean_ns: f64,
    block: usize,
    shape: (usize, usize, usize),
}

impl TableBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &mut self,
        x: PlaneLayout,
        w: PlaneLayout,
        block: usize,
        shape: (usize, usize, usize),
        kernel: &str,
        mean_ns: f64,
    ) {
        let key = (x, w, block_bucket_index(block), mnk_bucket_index(shape.0, shape.1, shape.2));
        let cand = Best { kernel: kernel.to_string(), mean_ns, block, shape };
        match self.best.get(&key) {
            Some(cur) if cur.mean_ns <= mean_ns => {}
            _ => {
                self.best.insert(key, cand);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.best.len()
    }
    pub fn is_empty(&self) -> bool {
        self.best.is_empty()
    }

    /// Render the `boosters-autotune-v1` document (entries in a
    /// deterministic key order so the artifact diffs cleanly).
    pub fn to_json(&self) -> Json {
        let mut keys: Vec<&Key> = self.best.keys().collect();
        keys.sort_by_key(|(x, w, bb, mb)| (x.label(), w.label(), *bb, *mb));
        let entries = keys.into_iter().map(|key| {
            let (x, w, bb, mb) = key;
            let b = &self.best[key];
            Json::obj(vec![
                ("x", Json::str(x.label())),
                ("w", Json::str(w.label())),
                ("block_bucket", Json::str(BLOCK_BUCKETS[*bb])),
                ("mnk_bucket", Json::str(MNK_BUCKETS[*mb])),
                ("kernel", Json::str(b.kernel.as_str())),
                ("block", Json::num(b.block as f64)),
                (
                    "shape",
                    Json::arr([
                        Json::num(b.shape.0 as f64),
                        Json::num(b.shape.1 as f64),
                        Json::num(b.shape.2 as f64),
                    ]),
                ),
                ("mean_ns", Json::num(b.mean_ns)),
            ])
        });
        Json::obj(vec![
            ("schema", Json::str("boosters-autotune-v1")),
            ("entries", Json::arr(entries)),
        ])
    }
}

/// Per-(backend, M×N×K bucket) counts of executed GEMM ops — the
/// "which kernel actually ran" accounting surfaced through
/// `ServiceStats` and the serve-sim `--json` artifact. Fixed-size so
/// it stays `Copy` alongside the other stats structs; slots are
/// assigned to backend names on first use.
pub const MAX_BACKENDS: usize = 6;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KernelOpCounts {
    names: [Option<&'static str>; MAX_BACKENDS],
    counts: [[u64; 3]; MAX_BACKENDS],
}

impl KernelOpCounts {
    pub fn record(&mut self, kernel: &'static str, mnk_bucket: usize) {
        let b = mnk_bucket.min(MNK_BUCKETS.len() - 1);
        for i in 0..MAX_BACKENDS {
            match self.names[i] {
                Some(n) if n == kernel => {
                    self.counts[i][b] += 1;
                    return;
                }
                None => {
                    self.names[i] = Some(kernel);
                    self.counts[i][b] += 1;
                    return;
                }
                _ => {}
            }
        }
        // More distinct backends than slots cannot happen with the
        // compiled-in set; if it ever does, keep the op counted.
        self.counts[MAX_BACKENDS - 1][b] += 1;
    }

    pub fn merge(&mut self, other: &KernelOpCounts) {
        for (kernel, bucket, n) in other.entries() {
            let b = MNK_BUCKETS.iter().position(|&l| l == bucket).unwrap_or(0);
            for _ in 0..n {
                self.record(kernel, b);
            }
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().flatten().sum()
    }

    /// Non-zero `(backend name, bucket label, ops)` triples.
    pub fn entries(&self) -> Vec<(&'static str, &'static str, u64)> {
        let mut out = Vec::new();
        for (i, name) in self.names.iter().enumerate() {
            let Some(name) = name else { continue };
            for (b, &n) in self.counts[i].iter().enumerate() {
                if n > 0 {
                    out.push((*name, MNK_BUCKETS[b], n));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_partition_the_problem_space() {
        assert_eq!(mnk_bucket_index(48, 48, 48), 0);
        assert_eq!(mnk_bucket_index(96, 96, 96), 1);
        assert_eq!(mnk_bucket_index(512, 512, 512), 2);
        assert_eq!(block_bucket_index(16), 0);
        assert_eq!(block_bucket_index(64), 1);
        assert_eq!(block_bucket_index(576), 2);
        assert_eq!(MNK_BUCKETS.len(), 3);
        assert_eq!(BLOCK_BUCKETS.len(), 3);
    }

    #[test]
    fn builder_keeps_the_fastest_backend_and_round_trips() {
        let mut b = TableBuilder::new();
        let (x, w) = (PlaneLayout::I4Packed, PlaneLayout::I4Packed);
        b.record(x, w, 64, (48, 48, 48), "scalar-tiled", 900.0);
        b.record(x, w, 64, (48, 48, 48), "autovec", 300.0);
        b.record(x, w, 64, (48, 48, 48), "avx2-widening", 500.0);
        b.record(PlaneLayout::I8, PlaneLayout::I8, 16, (512, 512, 512), "autovec", 1.0);
        assert_eq!(b.len(), 2);
        let text = b.to_json().render();
        let table = AutotuneTable::parse(&text).expect("round-trip");
        assert_eq!(table.len(), 2);
        // Fastest wins; lookup is by bucket, so a different small shape
        // with the same block bucket still hits.
        assert_eq!(table.lookup(x, w, 64, GemmShape::new(32, 40, 56)), Some("autovec"));
        assert_eq!(
            table.lookup(PlaneLayout::I8, PlaneLayout::I8, 16, GemmShape::new(512, 512, 512)),
            Some("autovec")
        );
        // Misses: unknown bucket combination.
        assert_eq!(table.lookup(x, w, 576, GemmShape::new(48, 48, 48)), None);
    }

    #[test]
    fn corrupt_tables_are_typed_errors() {
        assert!(AutotuneTable::parse("{ nope").is_err());
        assert!(AutotuneTable::parse("{\"schema\": \"v0\", \"entries\": []}").is_err());
        let bad_layout = r#"{"schema": "boosters-autotune-v1", "entries": [
            {"x": "i5", "w": "i8", "block_bucket": "b64", "mnk_bucket": "small",
             "kernel": "scalar-tiled"}]}"#;
        assert!(AutotuneTable::parse(bad_layout).is_err());
        let bad_bucket = r#"{"schema": "boosters-autotune-v1", "entries": [
            {"x": "i8", "w": "i8", "block_bucket": "b65", "mnk_bucket": "small",
             "kernel": "scalar-tiled"}]}"#;
        assert!(AutotuneTable::parse(bad_bucket).is_err());
        // An empty-entries placeholder parses fine and matches nothing.
        let empty = r#"{"schema": "boosters-autotune-v1",
            "status": "pending-toolchain-run", "entries": []}"#;
        let t = AutotuneTable::parse(empty).expect("placeholder parses");
        assert!(t.is_empty());
    }

    #[test]
    fn op_counts_accumulate_per_backend_and_bucket() {
        let mut c = KernelOpCounts::default();
        c.record("scalar-tiled", 0);
        c.record("scalar-tiled", 0);
        c.record("autovec", 2);
        assert_eq!(c.total(), 3);
        let mut d = KernelOpCounts::default();
        d.record("autovec", 2);
        d.merge(&c);
        assert_eq!(d.total(), 4);
        let entries = d.entries();
        assert!(entries.contains(&("scalar-tiled", "small", 2)));
        assert!(entries.contains(&("autovec", "large", 2)));
    }
}
