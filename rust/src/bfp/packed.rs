//! Packed structure-of-arrays BFP storage — the memory layout of the
//! whole numeric substrate.
//!
//! # Layout contract
//!
//! A [`BfpMatrix`] holds a logical `rows x cols` f32 matrix blocked
//! along its **columns** (the contraction axis) as two contiguous
//! planes:
//!
//! * **Mantissa plane** — storage chosen by
//!   [`BlockFormat::plane_layout`]: two's-complement nibble pairs
//!   (`m <= 4`, even block size — two mantissas per byte, the paper's
//!   4-bit storage density realized on the host), else one `i8`
//!   (`m <= 8`) or `i16` (`m <= 16`) per value. Rows are padded with
//!   zero mantissas to a whole number of blocks, so the row stride is
//!   `blocks_per_row * block_size` values and block `(r, k)` starts at
//!   value index `r * stride + k * block_size` (always byte-aligned in
//!   the nibble layout, because the block size is even).
//! * **Exponent plane** — one `i32` shared exponent per block,
//!   `blocks_per_row` entries per row; block `(r, k)` is at
//!   `r * blocks_per_row + k`.
//!
//! Encoding happens **once**; GEMM/dot kernels ([`super::gemm`]) then
//! stream the planes with no per-call re-encoding and no per-block heap
//! objects — the change that turns the host-side HBFP hot path from
//! allocation-bound into bandwidth-bound. The per-block scalar
//! [`super::block::BfpBlock`] survives as the reference implementation
//! the property tests cross-check against.
//!
//! # The block-writer encode core
//!
//! All encoding flows through **one** generic core parameterized by a
//! [`BlockWriter`] — the storage-layout half of an encode. The core
//! owns, in exactly one copy each:
//!
//! * the per-block quantization loop ([`encode_block_into`]: max-magnitude
//!   shared exponent, rounding-mode arms, clamping — mirrored operation
//!   for operation from `quantize_block_into` / `BfpBlock::encode_with`
//!   so all paths stay bit-compatible);
//! * the row-band / block-range / transposed column pool-split
//!   heuristics ([`encode_plane_dispatch`] and
//!   [`encode_transposed_plane`]) — a split-policy change lands in one
//!   place and applies to every layout.
//!
//! Writers only say where mantissas live: [`I8Writer`] / [`I16Writer`]
//! store one integer per value, and [`I4DirectWriter`] quantizes
//! **straight into nibble-packed bytes** (two 4-bit two's-complement
//! values per byte) with no intermediate i8 scratch block — the 4-bit
//! formats get the paper's storage density without paying a pack pass.
//! Every writer is bit-identical to the scalar reference encode by
//! construction: the quantization arithmetic is shared, only the final
//! store differs.
//!
//! Numerics are identical to [`super::quantize::quantize_flat`] (and
//! therefore to the python oracle pinned by the golden vectors), with
//! one documented exception: an integer mantissa cannot carry the sign
//! of `-0.0`, so packed round-trips canonicalize `-0.0` to `+0.0`.

use super::block::{scale_shift, BlockFormat};
use super::matrix::Mat;
use super::quantize::{exp2i, floor_log2, quantize_flat, Quantizer};
use super::rounding::{round_value, uniform_u01, RoundMode};
use crate::exec::pool::{Job, WorkerPool};
use anyhow::{anyhow, Result};

/// Storage layout of the mantissa plane — how encoded mantissas sit in
/// host memory. This is part of an operand's identity: GEMM kernels
/// dispatch on it ([`crate::bfp::kernels`]) and the exec operand cache
/// keys on it, so an entry encoded under one layout is never served to
/// a consumer expecting another.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlaneLayout {
    /// Two 4-bit two's-complement mantissas per byte (`m <= 4`,
    /// even block size): value `2j` in the low nibble of byte `j`,
    /// value `2j + 1` in the high nibble. Stored bits/value finally
    /// matches [`BlockFormat::bits_per_value`] for the paper's
    /// 4-bit formats.
    I4Packed,
    I8,
    I16,
}

impl PlaneLayout {
    /// Container bits per mantissa as stored on the host (the on-wire
    /// density claim uses [`BlockFormat::bits_per_value`], not this).
    pub fn container_bits(&self) -> u32 {
        match self {
            PlaneLayout::I4Packed => 4,
            PlaneLayout::I8 => 8,
            PlaneLayout::I16 => 16,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PlaneLayout::I4Packed => "i4x2",
            PlaneLayout::I8 => "i8",
            PlaneLayout::I16 => "i16",
        }
    }
}

/// Typed error for mantissa-plane layout mismatches — the safe
/// replacement for panicking plane destructures on the execution path.
/// Implements `std::error::Error`, so it downcasts cleanly through
/// `anyhow` chains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlaneLayoutError {
    pub expected: PlaneLayout,
    pub found: PlaneLayout,
}

impl std::fmt::Display for PlaneLayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mantissa plane holds {} but {} was requested",
            self.found.label(),
            self.expected.label()
        )
    }
}

impl std::error::Error for PlaneLayoutError {}

/// Integer types usable as mantissa-plane elements.
pub trait Mantissa: Copy + Send + Sync + 'static {
    /// True for 8-bit storage: block MACs fit i32 accumulators.
    const NARROW: bool;
    fn widen(self) -> i32;
    fn narrow(v: i32) -> Self;
}

impl Mantissa for i8 {
    const NARROW: bool = true;

    fn widen(self) -> i32 {
        self as i32
    }

    fn narrow(v: i32) -> Self {
        v as i8
    }
}

impl Mantissa for i16 {
    const NARROW: bool = false;

    fn widen(self) -> i32 {
        self as i32
    }

    fn narrow(v: i32) -> Self {
        v as i16
    }
}

/// Sign-extended low nibble of a packed byte (value `2j`).
#[inline]
pub fn nib_lo(b: u8) -> i8 {
    ((b << 4) as i8) >> 4
}

/// Sign-extended high nibble of a packed byte (value `2j + 1`).
#[inline]
pub fn nib_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// Value `i` of a nibble-packed byte stream — the single home of the
/// "value `i` lives in byte `i / 2`, low nibble when even" rule that
/// both the decode path ([`MantissaPlane::value`]) and the kernels'
/// nibble plane view share.
#[inline]
pub(crate) fn nib_at(bytes: &[u8], i: usize) -> i8 {
    let b = bytes[i >> 1];
    if i & 1 == 0 {
        nib_lo(b)
    } else {
        nib_hi(b)
    }
}

/// The contiguous mantissa plane, monomorphized by storage layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MantissaPlane {
    /// Nibble-packed 4-bit mantissas: `len / 2` bytes hold `len`
    /// values (see [`PlaneLayout::I4Packed`] for the nibble order).
    I4Packed(Vec<u8>),
    I8(Vec<i8>),
    I16(Vec<i16>),
}

impl MantissaPlane {
    /// Logical value count (for `I4Packed`, twice the byte count).
    pub fn len(&self) -> usize {
        match self {
            MantissaPlane::I4Packed(v) => 2 * v.len(),
            MantissaPlane::I8(v) => v.len(),
            MantissaPlane::I16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident host bytes of the plane — what the exec operand cache
    /// charges against its byte cap. Half of [`Self::len`] for the
    /// nibble-packed layout: the storage-density claim made load-bearing.
    pub fn resident_bytes(&self) -> usize {
        match self {
            MantissaPlane::I4Packed(v) => v.len(),
            MantissaPlane::I8(v) => v.len(),
            MantissaPlane::I16(v) => 2 * v.len(),
        }
    }

    pub fn layout(&self) -> PlaneLayout {
        match self {
            MantissaPlane::I4Packed(_) => PlaneLayout::I4Packed,
            MantissaPlane::I8(_) => PlaneLayout::I8,
            MantissaPlane::I16(_) => PlaneLayout::I16,
        }
    }

    /// The nibble-packed plane bytes, or a typed mismatch error.
    pub fn try_i4(&self) -> Result<&[u8], PlaneLayoutError> {
        match self {
            MantissaPlane::I4Packed(v) => Ok(v),
            other => Err(PlaneLayoutError {
                expected: PlaneLayout::I4Packed,
                found: other.layout(),
            }),
        }
    }

    /// The narrow byte plane, or a typed mismatch error.
    pub fn try_i8(&self) -> Result<&[i8], PlaneLayoutError> {
        match self {
            MantissaPlane::I8(v) => Ok(v),
            other => Err(PlaneLayoutError {
                expected: PlaneLayout::I8,
                found: other.layout(),
            }),
        }
    }

    /// The wide plane, or a typed mismatch error.
    pub fn try_i16(&self) -> Result<&[i16], PlaneLayoutError> {
        match self {
            MantissaPlane::I16(v) => Ok(v),
            other => Err(PlaneLayoutError {
                expected: PlaneLayout::I16,
                found: other.layout(),
            }),
        }
    }

    /// Unpacked value at logical index `i` (any layout) — decode-path
    /// and test convenience, not a kernel building block.
    pub fn value(&self, i: usize) -> i32 {
        match self {
            MantissaPlane::I4Packed(v) => nib_at(v, i) as i32,
            MantissaPlane::I8(v) => v[i] as i32,
            MantissaPlane::I16(v) => v[i] as i32,
        }
    }

    /// Resize to `len` zeroed values of `layout`, reusing the existing
    /// allocation when the layout is unchanged (the sweep hot path).
    /// `len` is the logical value count; `I4Packed` requires it even.
    fn prepare(&mut self, layout: PlaneLayout, len: usize) {
        match (&mut *self, layout) {
            (MantissaPlane::I4Packed(v), PlaneLayout::I4Packed) => {
                v.clear();
                v.resize(len / 2, 0);
            }
            (MantissaPlane::I8(v), PlaneLayout::I8) => {
                v.clear();
                v.resize(len, 0);
            }
            (MantissaPlane::I16(v), PlaneLayout::I16) => {
                v.clear();
                v.resize(len, 0);
            }
            (slot, PlaneLayout::I4Packed) => {
                debug_assert_eq!(len % 2, 0, "I4Packed planes hold value pairs");
                *slot = MantissaPlane::I4Packed(vec![0; len / 2])
            }
            (slot, PlaneLayout::I8) => *slot = MantissaPlane::I8(vec![0; len]),
            (slot, PlaneLayout::I16) => *slot = MantissaPlane::I16(vec![0; len]),
        }
    }
}

/// A whole matrix encoded as packed BFP planes (see module docs for the
/// layout contract). Encode once, GEMM many times.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BfpMatrix {
    pub fmt: BlockFormat,
    /// Logical row count.
    pub rows: usize,
    /// Logical column count (contraction axis; padded per row).
    pub cols: usize,
    /// Blocks per row = ceil(cols / block_size); row stride in the
    /// mantissa plane is `blocks_per_row * block_size`.
    pub blocks_per_row: usize,
    pub mantissas: MantissaPlane,
    pub exponents: Vec<i32>,
}

impl Default for BfpMatrix {
    fn default() -> Self {
        Self::empty()
    }
}

impl BfpMatrix {
    /// An empty reusable buffer; [`Self::encode_into`] gives it shape.
    pub fn empty() -> Self {
        Self {
            fmt: BlockFormat {
                mantissa_bits: 4,
                block_size: 1,
            },
            rows: 0,
            cols: 0,
            blocks_per_row: 0,
            mantissas: MantissaPlane::I8(Vec::new()),
            exponents: Vec::new(),
        }
    }

    /// Row stride of the mantissa plane in elements.
    pub fn row_stride(&self) -> usize {
        self.blocks_per_row * self.fmt.block_size
    }

    /// Total storage bits of the encoded planes at wire density
    /// (mantissa bits + amortized shared exponents) — by construction
    /// equal to [`BlockFormat::storage_bits`] summed over rows, which
    /// is what ties the software layout to the `hw_model` density
    /// arithmetic.
    pub fn storage_bits(&self) -> usize {
        self.rows * self.blocks_per_row * self.fmt.bits_per_block()
    }

    /// Encode a row-major `rows x cols` buffer. Blocking runs along
    /// columns with a zero-padded tail; every row restarts the
    /// stochastic-rounding stream at `base` exactly like the scalar
    /// `encode_row` path it replaces.
    pub fn encode(
        data: &[f32],
        rows: usize,
        cols: usize,
        fmt: BlockFormat,
        q: Quantizer,
    ) -> Result<Self> {
        let mut out = Self::empty();
        out.encode_into(data, rows, cols, fmt, q, 0)?;
        Ok(out)
    }

    /// [`Self::encode`] into an existing buffer, reusing allocations.
    /// Large tensors are encoded in parallel on the [`crate::exec`]
    /// pool — bit-identical to serial encoding, because every block is
    /// encoded independently (the stochastic stream is indexed by
    /// absolute block position).
    pub fn encode_into(
        &mut self,
        data: &[f32],
        rows: usize,
        cols: usize,
        fmt: BlockFormat,
        q: Quantizer,
        base: u32,
    ) -> Result<()> {
        self.encode_into_with(data, rows, cols, fmt, q, base, Some(crate::exec::global().pool()))
    }

    /// [`Self::encode_into`] on an explicit pool — used by
    /// [`crate::exec::ExecRuntime`] so private runtimes (including
    /// strict-serial ones) never spill work onto the global pool.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn encode_into_on(
        &mut self,
        pool: &WorkerPool,
        data: &[f32],
        rows: usize,
        cols: usize,
        fmt: BlockFormat,
        q: Quantizer,
        base: u32,
    ) -> Result<()> {
        self.encode_into_with(data, rows, cols, fmt, q, base, Some(pool))
    }

    /// Strictly serial [`Self::encode_into`], for callers that already
    /// run inside an exec-pool job.
    pub(crate) fn encode_into_serial(
        &mut self,
        data: &[f32],
        rows: usize,
        cols: usize,
        fmt: BlockFormat,
        q: Quantizer,
        base: u32,
    ) -> Result<()> {
        self.encode_into_with(data, rows, cols, fmt, q, base, None)
    }

    #[allow(clippy::too_many_arguments)]
    fn encode_into_with(
        &mut self,
        data: &[f32],
        rows: usize,
        cols: usize,
        fmt: BlockFormat,
        q: Quantizer,
        base: u32,
        pool: Option<&WorkerPool>,
    ) -> Result<()> {
        if rows * cols != data.len() {
            return Err(anyhow!("shape {rows}x{cols} != {} elems", data.len()));
        }
        self.reshape(rows, cols, fmt);
        let threads = encode_threads(data.len(), pool);
        match &mut self.mantissas {
            MantissaPlane::I4Packed(p) => encode_plane_dispatch::<I4DirectWriter>(
                data,
                rows,
                cols,
                fmt,
                q,
                base,
                p,
                &mut self.exponents,
                pool,
                threads,
            ),
            MantissaPlane::I8(p) => encode_plane_dispatch::<I8Writer>(
                data,
                rows,
                cols,
                fmt,
                q,
                base,
                p,
                &mut self.exponents,
                pool,
                threads,
            ),
            MantissaPlane::I16(p) => encode_plane_dispatch::<I16Writer>(
                data,
                rows,
                cols,
                fmt,
                q,
                base,
                p,
                &mut self.exponents,
                pool,
                threads,
            ),
        }
        Ok(())
    }

    /// Encode the **columns** of `w` (a `k x n` matrix) as packed rows —
    /// the weight-side layout of a GEMM, blocked along K — without
    /// materializing the transpose.
    pub fn encode_transposed(w: &Mat, fmt: BlockFormat, q: Quantizer) -> Result<Self> {
        let mut out = Self::empty();
        out.encode_transposed_into(w, fmt, q)?;
        Ok(out)
    }

    /// [`Self::encode_transposed`] into an existing buffer. Columns are
    /// independent, so wide weight matrices encode in parallel on the
    /// [`crate::exec`] pool, bit-identically to the serial path.
    pub fn encode_transposed_into(&mut self, w: &Mat, fmt: BlockFormat, q: Quantizer) -> Result<()> {
        self.encode_transposed_with(w, fmt, q, Some(crate::exec::global().pool()))
    }

    /// [`Self::encode_transposed_into`] on an explicit pool (see
    /// [`Self::encode_into_on`]).
    pub(crate) fn encode_transposed_on(
        &mut self,
        pool: &WorkerPool,
        w: &Mat,
        fmt: BlockFormat,
        q: Quantizer,
    ) -> Result<()> {
        self.encode_transposed_with(w, fmt, q, Some(pool))
    }

    fn encode_transposed_with(
        &mut self,
        w: &Mat,
        fmt: BlockFormat,
        q: Quantizer,
        pool: Option<&WorkerPool>,
    ) -> Result<()> {
        let (k, n) = (w.rows, w.cols);
        self.reshape(n, k, fmt);
        if n == 0 || k == 0 {
            return Ok(());
        }
        let stride = self.row_stride();
        let bpr = self.blocks_per_row;
        let threads = encode_threads(n * k, pool).min(n);
        match &mut self.mantissas {
            MantissaPlane::I4Packed(p) => encode_transposed_plane::<I4DirectWriter>(
                w,
                fmt,
                q,
                p,
                &mut self.exponents,
                stride,
                bpr,
                pool,
                threads,
            ),
            MantissaPlane::I8(p) => encode_transposed_plane::<I8Writer>(
                w,
                fmt,
                q,
                p,
                &mut self.exponents,
                stride,
                bpr,
                pool,
                threads,
            ),
            MantissaPlane::I16(p) => encode_transposed_plane::<I16Writer>(
                w,
                fmt,
                q,
                p,
                &mut self.exponents,
                stride,
                bpr,
                pool,
                threads,
            ),
        }
        Ok(())
    }

    fn reshape(&mut self, rows: usize, cols: usize, fmt: BlockFormat) {
        let bpr = cols.div_ceil(fmt.block_size);
        self.fmt = fmt;
        self.rows = rows;
        self.cols = cols;
        self.blocks_per_row = bpr;
        let nblocks = rows * bpr;
        self.exponents.clear();
        self.exponents.resize(nblocks, 0);
        self.mantissas.prepare(fmt.plane_layout(), nblocks * fmt.block_size);
    }

    /// Decode to the logical `rows x cols` f32 buffer (padding dropped),
    /// reusing `out`'s allocation.
    pub fn decode_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.rows * self.cols, 0.0);
        match &self.mantissas {
            MantissaPlane::I4Packed(p) => {
                decode_plane_packed(p, &self.exponents, self.rows, self.cols, self.fmt, out)
            }
            MantissaPlane::I8(p) => {
                decode_plane(p, &self.exponents, self.rows, self.cols, self.fmt, out)
            }
            MantissaPlane::I16(p) => {
                decode_plane(p, &self.exponents, self.rows, self.cols, self.fmt, out)
            }
        }
    }

    /// Decode to a fresh [`Mat`].
    pub fn to_mat(&self) -> Mat {
        let mut data = Vec::new();
        self.decode_into(&mut data);
        Mat {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    /// Decode a weight-side (`n` packed rows over K) matrix back to the
    /// `k x n` orientation a float GEMM consumes — the replacement for
    /// the old quantize/transpose/transpose-back dance in
    /// `dequant_gemm`.
    pub fn decode_transposed(&self) -> Mat {
        let (n, k) = (self.rows, self.cols);
        let mut out = Mat::zeros(k, n);
        match &self.mantissas {
            MantissaPlane::I4Packed(p) => {
                decode_plane_transposed_packed(p, &self.exponents, n, k, self.fmt, &mut out.data)
            }
            MantissaPlane::I8(p) => {
                decode_plane_transposed(p, &self.exponents, n, k, self.fmt, &mut out.data)
            }
            MantissaPlane::I16(p) => {
                decode_plane_transposed(p, &self.exponents, n, k, self.fmt, &mut out.data)
            }
        }
        out
    }

    /// Tiled, multi-threaded fixed-point GEMM against a weight-side
    /// operand encoded along the same contraction axis (see
    /// [`super::gemm::gemm_packed`]). `self` is `m x K`, `rhs_t` packs
    /// the `n` columns of a `K x n` weight matrix; the result is
    /// `m x n`, bit-identical to the scalar [`super::matrix::hbfp_gemm_scalar`]
    /// reference.
    pub fn gemm(&self, rhs_t: &BfpMatrix) -> Result<Mat> {
        super::gemm::gemm_packed(self, rhs_t)
    }
}

// --- the block-writer encode core (see module docs) -----------------------

/// Streaming destination for one block's quantized mantissas. The
/// quantization loop ([`encode_block_into`]) computes each mantissa as
/// an `i32` already clamped to the format's two's-complement range;
/// sinks only decide how it is stored. Values arrive in ascending
/// index order, which is what lets the nibble sink pack pairs without
/// read-modify-write hazards.
trait BlockSink {
    /// Store mantissa `m` for value `i` of the block.
    fn put(&mut self, i: usize, m: i32);
    /// Store zeros for all `len` values of the block (the subnormal
    /// short-circuit).
    fn zero(&mut self, len: usize);
}

/// One integer per value (i8 or i16 planes).
struct SliceSink<'a, T: Mantissa>(&'a mut [T]);

impl<T: Mantissa> BlockSink for SliceSink<'_, T> {
    #[inline]
    fn put(&mut self, i: usize, m: i32) {
        self.0[i] = T::narrow(m);
    }

    #[inline]
    fn zero(&mut self, len: usize) {
        self.0[..len].fill(T::narrow(0));
    }
}

/// Nibble-direct sink: value `2j` lands in the low nibble of byte `j`,
/// value `2j + 1` in the high nibble — written as it is quantized, no
/// i8 staging. The even-index store overwrites the whole byte (stale
/// high nibbles cannot leak from a reused buffer); the odd-index store
/// ORs the high nibble in.
struct NibbleSink<'a>(&'a mut [u8]);

impl BlockSink for NibbleSink<'_> {
    #[inline]
    fn put(&mut self, i: usize, m: i32) {
        let byte = &mut self.0[i >> 1];
        if i & 1 == 0 {
            *byte = (m as u8) & 0x0F;
        } else {
            *byte |= (m as u8) << 4;
        }
    }

    #[inline]
    fn zero(&mut self, len: usize) {
        self.0[..len / 2].fill(0);
    }
}

/// Encode one block: max-magnitude shared exponent, `m`-bit mantissas
/// (two's complement) via the selected rounding mode, streamed into
/// `sink`. Mirrors `quantize_block_into` / `BfpBlock::encode_with`
/// operation for operation so every path is bit-compatible — this is
/// the **single copy** of the quantization arithmetic behind all three
/// [`BlockWriter`]s.
fn encode_block_into<S: BlockSink>(v: &[f32], sink: &mut S, q: Quantizer, base_idx: u32) -> i32 {
    let mut maxabs = 0.0f32;
    for &x in v {
        let a = x.abs();
        if a > maxabs {
            maxabs = a;
        }
    }
    if maxabs < exp2i(-126) {
        sink.zero(v.len());
        return 0;
    }
    let e = floor_log2(maxabs);
    let m = q.m_bits as i32;
    let half = (1i64 << (m - 1)) as f32;
    let (lo, hi) = (-half, half - 1.0);
    // Multiplying by the exact reciprocal of the power-of-two interval
    // is bit-identical to dividing by it (IEEE-754); fall back to
    // division when the reciprocal exponent leaves the normal range.
    let sinv_e = -scale_shift(e, q.m_bits);
    let sinv = if (-126..=127).contains(&sinv_e) {
        Some(exp2i(sinv_e))
    } else {
        None
    };
    match (q.mode, sinv) {
        (RoundMode::NearestEven, Some(si)) => {
            for (i, &x) in v.iter().enumerate() {
                sink.put(i, (x * si).round_ties_even().clamp(lo, hi) as i32);
            }
        }
        (RoundMode::Stochastic, Some(si)) => {
            for (i, &x) in v.iter().enumerate() {
                let u = uniform_u01(base_idx.wrapping_add(i as u32), q.seed);
                sink.put(i, (x * si + u).floor().clamp(lo, hi) as i32);
            }
        }
        (_, None) => {
            let s = exp2i(scale_shift(e, q.m_bits));
            for (i, &x) in v.iter().enumerate() {
                let r = round_value(x / s, q.mode, base_idx.wrapping_add(i as u32), q.seed);
                sink.put(i, r.clamp(lo, hi) as i32);
            }
        }
    }
    e
}

/// The storage-layout half of an encode: how many plane elements back a
/// run of logical values, and how one block's mantissas are stored.
/// The generic encode core (serial loops, pool splits) is written once
/// against this trait; see the module docs.
trait BlockWriter: 'static {
    /// Raw element of the mantissa plane this writer fills.
    type Elem: Copy + Send + Sync + 'static;

    /// Plane elements backing `values` logical values. `values` is
    /// always a whole number of blocks, so the nibble writer (two
    /// values per element) never sees an odd count.
    fn elems(values: usize) -> usize;

    /// Quantize one (already padded) block straight into its plane
    /// destination; returns the block's shared exponent.
    fn encode_block(v: &[f32], dst: &mut [Self::Elem], q: Quantizer, base_idx: u32) -> i32;
}

/// One i8 per mantissa (`4 < m <= 8`, or `m <= 4` with an odd block).
struct I8Writer;

impl BlockWriter for I8Writer {
    type Elem = i8;

    #[inline]
    fn elems(values: usize) -> usize {
        values
    }

    #[inline]
    fn encode_block(v: &[f32], dst: &mut [i8], q: Quantizer, base_idx: u32) -> i32 {
        debug_assert_eq!(v.len(), dst.len());
        encode_block_into(v, &mut SliceSink(dst), q, base_idx)
    }
}

/// One i16 per mantissa (`8 < m <= 16`).
struct I16Writer;

impl BlockWriter for I16Writer {
    type Elem = i16;

    #[inline]
    fn elems(values: usize) -> usize {
        values
    }

    #[inline]
    fn encode_block(v: &[f32], dst: &mut [i16], q: Quantizer, base_idx: u32) -> i32 {
        debug_assert_eq!(v.len(), dst.len());
        encode_block_into(v, &mut SliceSink(dst), q, base_idx)
    }
}

/// Nibble-direct writer for [`PlaneLayout::I4Packed`]: quantizes each
/// value pair straight into one packed byte — no i8 scratch block, no
/// second pass. Blocks always start byte-aligned (even block sizes
/// only), so a block's destination is exactly `block_size / 2` bytes.
struct I4DirectWriter;

impl BlockWriter for I4DirectWriter {
    type Elem = u8;

    #[inline]
    fn elems(values: usize) -> usize {
        values / 2
    }

    #[inline]
    fn encode_block(v: &[f32], dst: &mut [u8], q: Quantizer, base_idx: u32) -> i32 {
        debug_assert_eq!(v.len(), 2 * dst.len());
        encode_block_into(v, &mut NibbleSink(dst), q, base_idx)
    }
}

/// Encode one already-padded row (`len == blocks * block_size`).
fn encode_padded_row<W: BlockWriter>(
    row: &[f32],
    fmt: BlockFormat,
    q: Quantizer,
    base: u32,
    plane_row: &mut [W::Elem],
    exps_row: &mut [i32],
) {
    let b = fmt.block_size;
    let eb = W::elems(b);
    for (bi, (src, dst)) in row.chunks(b).zip(plane_row.chunks_mut(eb)).enumerate() {
        let idx = base.wrapping_add((bi * b) as u32);
        exps_row[bi] = W::encode_block(src, dst, q, idx);
    }
}

/// Encode blocks `k0 .. k0 + exps_chunk.len()` of one logical row of
/// `cols` values. Blocks are indexed absolutely (`k0` offsets both the
/// ragged-tail check and the stochastic stream), so any partition of a
/// row's block range reproduces the serial encoding bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn encode_blocks_range<W: BlockWriter>(
    row: &[f32],
    cols: usize,
    k0: usize,
    fmt: BlockFormat,
    q: Quantizer,
    base: u32,
    plane_chunk: &mut [W::Elem],
    exps_chunk: &mut [i32],
    tail: &mut [f32],
) {
    let b = fmt.block_size;
    let eb = W::elems(b);
    for (i, exp_slot) in exps_chunk.iter_mut().enumerate() {
        let bi = k0 + i;
        let idx = base.wrapping_add((bi * b) as u32);
        let lo = bi * b;
        let hi = ((bi + 1) * b).min(cols);
        let dst = &mut plane_chunk[i * eb..(i + 1) * eb];
        *exp_slot = if hi - lo == b {
            W::encode_block(&row[lo..hi], dst, q, idx)
        } else {
            tail.fill(0.0);
            tail[..hi - lo].copy_from_slice(&row[lo..hi]);
            W::encode_block(tail, dst, q, idx)
        };
    }
}

#[allow(clippy::too_many_arguments)]
fn encode_plane<W: BlockWriter>(
    data: &[f32],
    rows: usize,
    cols: usize,
    fmt: BlockFormat,
    q: Quantizer,
    base: u32,
    plane: &mut [W::Elem],
    exps: &mut [i32],
) {
    let b = fmt.block_size;
    let bpr = cols.div_ceil(b);
    let estride = W::elems(bpr * b);
    // One scratch block for the ragged tail, hoisted out of all loops.
    let mut tail = vec![0.0f32; b];
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        encode_blocks_range::<W>(
            row,
            cols,
            0,
            fmt,
            q,
            base,
            &mut plane[r * estride..(r + 1) * estride],
            &mut exps[r * bpr..(r + 1) * bpr],
            &mut tail,
        );
    }
}

/// Tensors below this size are always encoded serially (pool dispatch
/// would cost more than it saves).
const PARALLEL_MIN_ENCODE: usize = 1 << 16;

fn encode_threads(elems: usize, pool: Option<&WorkerPool>) -> usize {
    match pool {
        Some(p) if elems >= PARALLEL_MIN_ENCODE => p.threads().clamp(1, 16),
        _ => 1,
    }
}

/// Serial-or-parallel plane encode: multi-row tensors split into row
/// bands, single-row tensors split along the block axis. Either split
/// is bit-identical to the serial loop (per-block independence). This
/// is the **only copy** of the row-band / block-range split policy —
/// every [`PlaneLayout`] runs it through its [`BlockWriter`].
#[allow(clippy::too_many_arguments)]
fn encode_plane_dispatch<W: BlockWriter>(
    data: &[f32],
    rows: usize,
    cols: usize,
    fmt: BlockFormat,
    q: Quantizer,
    base: u32,
    plane: &mut [W::Elem],
    exps: &mut [i32],
    pool: Option<&WorkerPool>,
    threads: usize,
) {
    let b = fmt.block_size;
    let bpr = cols.div_ceil(b);
    let pool = match pool {
        Some(p) if threads > 1 && (rows >= 2 || bpr >= 2) => p,
        _ => {
            encode_plane::<W>(data, rows, cols, fmt, q, base, plane, exps);
            return;
        }
    };
    let estride = W::elems(bpr * b);
    if rows >= 2 {
        let band = rows.div_ceil(threads.min(rows));
        let jobs: Vec<Job> = plane
            .chunks_mut(band * estride)
            .zip(exps.chunks_mut(band * bpr))
            .zip(data.chunks(band * cols))
            .map(|((pchunk, echunk), dchunk)| {
                Box::new(move || {
                    encode_plane::<W>(
                        dchunk,
                        dchunk.len() / cols,
                        cols,
                        fmt,
                        q,
                        base,
                        pchunk,
                        echunk,
                    );
                }) as Job
            })
            .collect();
        pool.scope_run(jobs);
    } else {
        let kband = bpr.div_ceil(threads.min(bpr));
        let jobs: Vec<Job> = plane
            .chunks_mut(W::elems(kband * b))
            .zip(exps.chunks_mut(kband))
            .enumerate()
            .map(|(t, (pchunk, echunk))| {
                let k0 = t * kband;
                Box::new(move || {
                    let mut tail = vec![0.0f32; b];
                    encode_blocks_range::<W>(
                        data,
                        cols,
                        k0,
                        fmt,
                        q,
                        base,
                        pchunk,
                        echunk,
                        &mut tail,
                    );
                }) as Job
            })
            .collect();
        pool.scope_run(jobs);
    }
}

/// Parallel column-wise weight encode: each job gathers and encodes a
/// contiguous range of columns into its own plane band. The **only
/// copy** of the transposed pool-split policy, layout-generic like
/// [`encode_plane_dispatch`].
#[allow(clippy::too_many_arguments)]
fn encode_transposed_plane<W: BlockWriter>(
    w: &Mat,
    fmt: BlockFormat,
    q: Quantizer,
    plane: &mut [W::Elem],
    exps: &mut [i32],
    stride: usize,
    bpr: usize,
    pool: Option<&WorkerPool>,
    threads: usize,
) {
    let n = w.cols;
    let pool = match pool {
        Some(p) if threads > 1 && n >= 2 => p,
        _ => {
            encode_transposed_cols::<W>(w, fmt, q, 0, plane, exps, stride, bpr);
            return;
        }
    };
    let jband = n.div_ceil(threads);
    let estride = W::elems(stride);
    let jobs: Vec<Job> = plane
        .chunks_mut(jband * estride)
        .zip(exps.chunks_mut(jband * bpr))
        .enumerate()
        .map(|(t, (pchunk, echunk))| {
            let j0 = t * jband;
            Box::new(move || {
                encode_transposed_cols::<W>(w, fmt, q, j0, pchunk, echunk, stride, bpr);
            }) as Job
        })
        .collect();
    pool.scope_run(jobs);
}

/// Gather-and-encode columns `j0 ..` of `w` into the given plane band.
#[allow(clippy::too_many_arguments)]
fn encode_transposed_cols<W: BlockWriter>(
    w: &Mat,
    fmt: BlockFormat,
    q: Quantizer,
    j0: usize,
    plane_chunk: &mut [W::Elem],
    exps_chunk: &mut [i32],
    stride: usize,
    bpr: usize,
) {
    let (k, n) = (w.rows, w.cols);
    let estride = W::elems(stride);
    let ncols = plane_chunk.len() / estride;
    // Gather one padded column at a time; the zero tail is written once
    // and never dirtied (only the first k entries are reused).
    let mut col = vec![0.0f32; stride];
    for jj in 0..ncols {
        let j = j0 + jj;
        for (i, c) in col[..k].iter_mut().enumerate() {
            *c = w.data[i * n + j];
        }
        encode_padded_row::<W>(
            &col,
            fmt,
            q,
            0,
            &mut plane_chunk[jj * estride..(jj + 1) * estride],
            &mut exps_chunk[jj * bpr..(jj + 1) * bpr],
        );
    }
}

// --- nibble-packed (I4Packed) decode --------------------------------------
//
// Encode flows through the block-writer core above (the nibble-direct
// [`I4DirectWriter`]); decode keeps explicit packed loops because it
// reads the plane, not writes it. Blocks always start byte-aligned:
// the layout is only selected for even block sizes, so block `k` of
// row `r` begins at nibble `r * stride + k * b`, an even offset.

/// Packed counterpart of [`decode_plane`].
fn decode_plane_packed(
    plane: &[u8],
    exps: &[i32],
    rows: usize,
    cols: usize,
    fmt: BlockFormat,
    out: &mut [f32],
) {
    let b = fmt.block_size;
    let bpr = cols.div_ceil(b);
    let stride = bpr * b;
    for r in 0..rows {
        for bi in 0..bpr {
            let s = exp2i(scale_shift(exps[r * bpr + bi], fmt.mantissa_bits));
            let lo = bi * b;
            let hi = ((bi + 1) * b).min(cols);
            // Block start is even (b is even), so nibbles pair up
            // within the block: byte j holds values (2j, 2j + 1).
            let bytes = &plane[(r * stride + lo) / 2..(r * stride + lo + b) / 2];
            let dst = &mut out[r * cols + lo..r * cols + hi];
            for (t, o) in dst.iter_mut().enumerate() {
                *o = nib_at(bytes, t) as f32 * s;
            }
        }
    }
}

/// Packed counterpart of [`decode_plane_transposed`].
fn decode_plane_transposed_packed(
    plane: &[u8],
    exps: &[i32],
    n: usize,
    k: usize,
    fmt: BlockFormat,
    out: &mut [f32],
) {
    let b = fmt.block_size;
    let bpr = k.div_ceil(b);
    let stride = bpr * b;
    for j in 0..n {
        for bi in 0..bpr {
            let s = exp2i(scale_shift(exps[j * bpr + bi], fmt.mantissa_bits));
            let lo = bi * b;
            let hi = ((bi + 1) * b).min(k);
            let bytes = &plane[(j * stride + lo) / 2..(j * stride + lo + b) / 2];
            for t in lo..hi {
                out[t * n + j] = nib_at(bytes, t - lo) as f32 * s;
            }
        }
    }
}

fn decode_plane<T: Mantissa>(
    plane: &[T],
    exps: &[i32],
    rows: usize,
    cols: usize,
    fmt: BlockFormat,
    out: &mut [f32],
) {
    let b = fmt.block_size;
    let bpr = cols.div_ceil(b);
    let stride = bpr * b;
    for r in 0..rows {
        for bi in 0..bpr {
            let s = exp2i(scale_shift(exps[r * bpr + bi], fmt.mantissa_bits));
            let lo = bi * b;
            let hi = ((bi + 1) * b).min(cols);
            let src = &plane[r * stride + lo..r * stride + lo + (hi - lo)];
            let dst = &mut out[r * cols + lo..r * cols + hi];
            for (o, &mq) in dst.iter_mut().zip(src) {
                *o = mq.widen() as f32 * s;
            }
        }
    }
}

fn decode_plane_transposed<T: Mantissa>(
    plane: &[T],
    exps: &[i32],
    n: usize,
    k: usize,
    fmt: BlockFormat,
    out: &mut [f32],
) {
    let b = fmt.block_size;
    let bpr = k.div_ceil(b);
    let stride = bpr * b;
    for j in 0..n {
        for bi in 0..bpr {
            let s = exp2i(scale_shift(exps[j * bpr + bi], fmt.mantissa_bits));
            let lo = bi * b;
            let hi = ((bi + 1) * b).min(k);
            for t in lo..hi {
                out[t * n + j] = plane[j * stride + t].widen() as f32 * s;
            }
        }
    }
}

/// Quantize a flat tensor through the packed carrier — same semantics
/// (blocking, padding, stochastic stream, site salt) as
/// [`quantize_flat`], reusing `scratch` and `out` across calls so
/// sweeps over many `(m, b)` points allocate nothing after warmup.
pub fn quantize_packed_into(
    t: &[f32],
    block: usize,
    q: Quantizer,
    site: u32,
    scratch: &mut BfpMatrix,
    out: &mut Vec<f32>,
) -> Result<()> {
    if q.is_bypass() {
        out.clear();
        out.extend_from_slice(t);
        return Ok(());
    }
    if !(2..=16).contains(&q.m_bits) {
        // Mantissas beyond the integer carrier (17..=22): delegate.
        let flat = quantize_flat(t, block, q, site);
        out.clear();
        out.extend_from_slice(&flat);
        return Ok(());
    }
    let fmt = BlockFormat::new(q.m_bits, block)?;
    scratch.encode_into(t, 1, t.len(), fmt, q, site.wrapping_mul(40503))?;
    scratch.decode_into(out);
    Ok(())
}

/// Convenience wrapper over [`quantize_packed_into`] with fresh buffers.
pub fn quantize_packed(t: &[f32], block: usize, q: Quantizer, site: u32) -> Vec<f32> {
    let mut scratch = BfpMatrix::empty();
    let mut out = Vec::new();
    quantize_packed_into(t, block, q, site, &mut scratch, &mut out)
        .expect("block size is validated by callers");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::BfpTensor;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(1.0)).collect()
    }

    /// f32 equality that identifies +/-0 but is bitwise otherwise.
    fn same(a: f32, b: f32) -> bool {
        (a == 0.0 && b == 0.0) || a.to_bits() == b.to_bits()
    }

    #[test]
    fn plane_layout_by_mantissa_width_and_block_parity() {
        // m <= 4 with an even block packs two mantissas per byte; odd
        // blocks would start mid-byte and stay on the byte plane.
        assert_eq!(BlockFormat::new(4, 64).unwrap().plane_layout(), PlaneLayout::I4Packed);
        assert_eq!(BlockFormat::new(2, 16).unwrap().plane_layout(), PlaneLayout::I4Packed);
        assert_eq!(BlockFormat::new(4, 49).unwrap().plane_layout(), PlaneLayout::I8);
        assert_eq!(BlockFormat::new(5, 64).unwrap().plane_layout(), PlaneLayout::I8);
        assert_eq!(BlockFormat::new(8, 64).unwrap().plane_layout(), PlaneLayout::I8);
        assert_eq!(BlockFormat::new(9, 64).unwrap().plane_layout(), PlaneLayout::I16);
        assert_eq!(BlockFormat::new(16, 64).unwrap().plane_layout(), PlaneLayout::I16);
        assert_eq!(PlaneLayout::I4Packed.container_bits(), 4);
        assert_eq!(PlaneLayout::I8.container_bits(), 8);
        assert_eq!(PlaneLayout::I4Packed.label(), "i4x2");
        assert_eq!(PlaneLayout::I16.label(), "i16");
    }

    #[test]
    fn nibble_codec_round_trips_the_4bit_range() {
        // All 256 nibble pairs: the nibble-direct sink packs straight
        // into the byte, and sign extension recovers both
        // two's-complement values in [-8, 7] — even over a dirty
        // buffer (the even-index store must clear stale high nibbles).
        let mut scratch = [0xFFu8; 1];
        for lo in -8i32..=7 {
            for hi in -8i32..=7 {
                let mut sink = NibbleSink(&mut scratch);
                sink.put(0, lo);
                sink.put(1, hi);
                assert_eq!(nib_lo(scratch[0]) as i32, lo, "lo {lo} hi {hi}");
                assert_eq!(nib_hi(scratch[0]) as i32, hi, "lo {lo} hi {hi}");
            }
        }
        // The zero short-circuit clears the packed bytes too.
        let mut dirty = [0xAAu8; 2];
        NibbleSink(&mut dirty).zero(4);
        assert_eq!(dirty, [0, 0]);
    }

    #[test]
    fn i4packed_halves_plane_bytes_and_round_trips() {
        // The acceptance criterion: stored plane bytes for m = 4
        // operands halve versus the byte-per-mantissa seed layout,
        // while decode stays bit-identical to the flat quantizer.
        let x = randn(1000, 17);
        let fmt = BlockFormat::new(4, 64).unwrap();
        let q = Quantizer::nearest(4);
        let p = BfpMatrix::encode(&x, 4, 250, fmt, q).unwrap();
        assert_eq!(p.mantissas.layout(), PlaneLayout::I4Packed);
        let values = p.mantissas.len();
        assert_eq!(values, 4 * p.blocks_per_row * 64);
        assert_eq!(p.mantissas.resident_bytes(), values / 2, "two mantissas per byte");
        assert_eq!(p.mantissas.try_i4().unwrap().len(), values / 2);
        // Wire-density accounting is unchanged by the host layout.
        assert_eq!(p.storage_bits(), 4 * fmt.storage_bits(250));
        // Values decode exactly as the flat quantizer emits them.
        let mut got = Vec::new();
        p.decode_into(&mut got);
        for r in 0..4 {
            let want = quantize_flat(&x[r * 250..(r + 1) * 250], 64, q, 0);
            for (i, (g, w)) in got[r * 250..(r + 1) * 250].iter().zip(&want).enumerate() {
                assert!(same(*g, *w), "row {r} elem {i}: {g} vs {w}");
            }
        }
        // Per-value accessor agrees with the decoded plane.
        let stride = p.row_stride();
        for r in 0..4 {
            for c in 0..250 {
                let q4 = p.mantissas.value(r * stride + c);
                assert!(
                    (-8..=7).contains(&q4),
                    "mantissa out of 4-bit range: {q4}"
                );
            }
        }
    }

    #[test]
    fn i4packed_transposed_encode_matches_row_encode_of_transpose() {
        let w = Mat::new(38, 6, randn(228, 18)).unwrap();
        let fmt = BlockFormat::new(4, 16).unwrap();
        let q = Quantizer::nearest(4);
        let a = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
        let wt = w.transpose();
        let b = BfpMatrix::encode(&wt.data, wt.rows, wt.cols, fmt, q).unwrap();
        assert_eq!(a.exponents, b.exponents);
        assert_eq!(a.mantissas.try_i4().unwrap(), b.mantissas.try_i4().unwrap());
        let back = a.decode_transposed();
        assert_eq!((back.rows, back.cols), (w.rows, w.cols));
        assert_eq!(back.data, b.to_mat().transpose().data);
    }

    #[test]
    fn encode_decode_matches_quantize_flat() {
        let x = randn(700, 1);
        for (m, b) in [(2u32, 8usize), (4, 16), (6, 64), (8, 49), (12, 64), (16, 576)] {
            let fmt = BlockFormat::new(m, b).unwrap();
            let q = Quantizer::nearest(m);
            let p = BfpMatrix::encode(&x, 1, x.len(), fmt, q).unwrap();
            let mut got = Vec::new();
            p.decode_into(&mut got);
            let want = quantize_flat(&x, b, q, 0);
            assert_eq!(got.len(), want.len());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(same(*g, *w), "m={m} b={b} elem {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn stochastic_stream_matches_flat_quantizer() {
        let x = randn(300, 2);
        for site in [0u32, 3, 17] {
            let q = Quantizer::stochastic(4, 9);
            let got = quantize_packed(&x, 64, q, site);
            let want = quantize_flat(&x, 64, q, site);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(same(*g, *w), "site={site} elem {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn quantize_packed_bypass_and_wide_mantissas() {
        let x = randn(130, 3);
        assert_eq!(quantize_packed(&x, 16, Quantizer::nearest(23), 0), x);
        let got = quantize_packed(&x, 16, Quantizer::nearest(18), 0);
        let want = quantize_flat(&x, 16, Quantizer::nearest(18), 0);
        assert_eq!(got, want);
    }

    #[test]
    fn matrix_rows_restart_the_block_stream() {
        // Encoding (2, 40) must equal encoding each row independently.
        let x = randn(80, 4);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let q = Quantizer::nearest(4);
        let both = BfpMatrix::encode(&x, 2, 40, fmt, q).unwrap();
        let mut got = Vec::new();
        both.decode_into(&mut got);
        for r in 0..2 {
            let row = quantize_flat(&x[r * 40..(r + 1) * 40], 16, q, 0);
            for (i, (g, w)) in got[r * 40..(r + 1) * 40].iter().zip(&row).enumerate() {
                assert!(same(*g, *w), "row {r} elem {i}");
            }
        }
    }

    #[test]
    fn transposed_encode_matches_explicit_transpose() {
        let w = Mat::new(37, 5, randn(185, 5)).unwrap();
        let fmt = BlockFormat::new(6, 16).unwrap();
        let q = Quantizer::nearest(6);
        let a = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
        let wt = w.transpose();
        let b = BfpMatrix::encode(&wt.data, wt.rows, wt.cols, fmt, q).unwrap();
        assert_eq!(a.exponents, b.exponents);
        // Typed accessors replace the old panic-on-mismatch destructure.
        assert_eq!(
            a.mantissas.try_i8().expect("m=6 uses the narrow plane"),
            b.mantissas.try_i8().expect("m=6 uses the narrow plane")
        );
        assert_eq!(
            a.mantissas.try_i16().unwrap_err(),
            PlaneLayoutError {
                expected: PlaneLayout::I16,
                found: PlaneLayout::I8,
            }
        );
        assert_eq!(
            a.mantissas.try_i4().unwrap_err(),
            PlaneLayoutError {
                expected: PlaneLayout::I4Packed,
                found: PlaneLayout::I8,
            }
        );
        // And decode_transposed returns the k x n orientation.
        let back = a.decode_transposed();
        assert_eq!((back.rows, back.cols), (w.rows, w.cols));
        let direct = b.to_mat().transpose();
        assert_eq!(back.data, direct.data);
    }

    #[test]
    fn storage_accounting_matches_scalar_tensor() {
        let x = randn(100, 6);
        let fmt = BlockFormat::new(4, 64).unwrap();
        let p = BfpMatrix::encode(&x, 1, x.len(), fmt, Quantizer::nearest(4)).unwrap();
        let t = BfpTensor::encode(&x, fmt).unwrap();
        assert_eq!(p.storage_bits(), t.storage_bits());
        assert_eq!(p.storage_bits(), fmt.storage_bits(x.len()));
        assert_eq!(p.row_stride(), 2 * 64);
    }

    #[test]
    fn buffer_reuse_across_shapes_and_layouts() {
        let mut m = BfpMatrix::empty();
        let mut out = Vec::new();
        let x = randn(640, 7);
        // Transitions cover nibble -> i16 -> nibble -> i8 re-preparation.
        for (mbits, b, n) in [(4u32, 64usize, 640usize), (12, 16, 100), (4, 576, 640), (6, 25, 33)] {
            let fmt = BlockFormat::new(mbits, b).unwrap();
            let q = Quantizer::nearest(mbits);
            m.encode_into(&x[..n], 1, n, fmt, q, 0).unwrap();
            assert_eq!(m.mantissas.layout(), fmt.plane_layout());
            m.decode_into(&mut out);
            let want = quantize_flat(&x[..n], b, q, 0);
            for (i, (g, w)) in out.iter().zip(&want).enumerate() {
                assert!(same(*g, *w), "m={mbits} b={b} elem {i}");
            }
        }
    }

    #[test]
    fn parallel_encode_bit_identical_to_serial() {
        // Above PARALLEL_MIN_ENCODE the pool path kicks in; both the
        // multi-row (row-band) and single-row (block-range) splits must
        // reproduce the serial planes exactly, ragged tails included.
        let n = PARALLEL_MIN_ENCODE + 1234;
        let x = randn(n.max(300 * 256), 11);
        for (rows, cols) in [(1usize, n), (128, n / 128)] {
            let data = &x[..rows * cols];
            for q in [Quantizer::nearest(4), Quantizer::stochastic(4, 77)] {
                let mut par = BfpMatrix::empty();
                par.encode_into(data, rows, cols, BlockFormat::new(4, 64).unwrap(), q, 5)
                    .unwrap();
                let mut ser = BfpMatrix::empty();
                ser.encode_into_serial(data, rows, cols, BlockFormat::new(4, 64).unwrap(), q, 5)
                    .unwrap();
                assert_eq!(par.exponents, ser.exponents, "rows={rows}");
                // m=4, even block: the nibble-packed plane, byte-compared.
                assert_eq!(
                    par.mantissas.try_i4().unwrap(),
                    ser.mantissas.try_i4().unwrap(),
                    "rows={rows}"
                );
            }
        }
        // Transposed (weight-side) parallel encode, wide enough to split.
        let w = Mat::new(300, 256, x[..300 * 256].to_vec()).unwrap();
        let fmt = BlockFormat::new(6, 64).unwrap();
        let q = Quantizer::nearest(6);
        let par = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
        let wt = w.transpose();
        let mut ser = BfpMatrix::empty();
        ser.encode_into_serial(&wt.data, wt.rows, wt.cols, fmt, q, 0).unwrap();
        assert_eq!(par.exponents, ser.exponents);
        assert_eq!(par.mantissas.try_i8().unwrap(), ser.mantissas.try_i8().unwrap());
    }

    #[test]
    fn shape_validation() {
        let fmt = BlockFormat::new(4, 16).unwrap();
        assert!(BfpMatrix::encode(&[0.0; 10], 3, 4, fmt, Quantizer::nearest(4)).is_err());
        let empty = BfpMatrix::encode(&[], 0, 0, fmt, Quantizer::nearest(4)).unwrap();
        assert_eq!(empty.storage_bits(), 0);
        assert!(empty.mantissas.is_empty());
    }
}
