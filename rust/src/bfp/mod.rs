//! Software Block Floating Point — the numeric-format substrate.
//!
//! From-scratch implementation of the paper's BFP encoding:
//! a block of `b` values shares one (10-bit) exponent; each value keeps an
//! `m`-bit two's-complement mantissa. Dot products between BFP blocks are
//! pure fixed-point integer arithmetic plus one exponent add ([`dot`]).
//!
//! [`quantize`] is **bit-exact** against the python oracle
//! (`python/compile/kernels/ref.py`) — pinned by the golden vectors in
//! `artifacts/golden_bfp.json` (integration test `rust/tests/golden_bfp.rs`)
//! — so host-side analysis (Wasserstein sweeps, Fig 1) sees exactly the
//! numerics the AOT-compiled training graph applies.
//!
//! # Packed memory layout (the production datapath)
//!
//! The hot path stores tensors as **structure-of-arrays planes** in
//! [`packed::BfpMatrix`], not as per-block objects:
//!
//! * mantissa plane — storage chosen by
//!   [`block::BlockFormat::plane_layout`]: nibble-packed pairs of
//!   4-bit two's-complement mantissas (m <= 4, even blocks — the
//!   paper's 4-bit storage density realized on the host), else
//!   contiguous `i8` (m <= 8) or `i16` (m <= 16) integers; rows are
//!   padded to whole blocks, stride = `blocks_per_row * block_size`;
//! * exponent plane — one `i32` per block, `blocks_per_row` per row;
//! * scale rule — a mantissa decodes as `q * 2^scale_shift(e, m)` with
//!   [`block::scale_shift`]`(e, m) = e - m + 2` (Eq. 1), the single
//!   home of the `+2`.
//!
//! [`gemm`] runs a cache-tiled, register-blocked, row-band-parallel
//! fixed-point GEMM over those planes (thread partitioning is by whole
//! output rows, so parallel results are bit-identical to serial). The
//! micro-kernel layer is the [`kernels`] registry: runtime-dispatched
//! backends ([`ScalarTiledKernel`], [`kernels::AutovecKernel`], AVX2 /
//! AVX-512-VNNI / NEON where detected) behind the [`GemmKernel`]
//! trait, selected per operand [`PlaneLayout`] pair and problem-shape
//! bucket (autotune table, `BOOSTERS_AUTOTUNE`) and overridable with
//! `BOOSTERS_KERNEL`.
//! Bands execute as work items on the persistent [`crate::exec`] pool,
//! and weight-side encodings are reused across calls through the exec
//! operand cache. Encoding happens once per operand; the scalar
//! [`block::BfpBlock`] / [`matrix::hbfp_gemm_scalar`] path is retained
//! as the reference the property tests cross-check bit-for-bit against
//! every registered backend.

pub mod block;
pub mod dot;
pub mod gemm;
pub mod kernels;
pub mod matrix;
pub mod packed;
pub mod quantize;
pub mod rounding;

pub use block::{scale_shift, BfpBlock, BfpTensor, BlockFormat};
pub use dot::{bfp_dot_blocks, bfp_dot_fixed_point, dequant_dot};
pub use gemm::{gemm_packed, gemm_packed_with, packed_dot};
pub use kernels::{
    active_kernel, registry, AutotuneTable, AutovecKernel, BandTask, GemmKernel, GemmShape,
    KernelOpCounts, KernelRegistry, ScalarTiledKernel,
};
pub use matrix::{dequant_gemm, hbfp_gemm, hbfp_gemm_scalar, Mat};
pub use packed::{
    nib_hi, nib_lo, quantize_packed, quantize_packed_into, BfpMatrix, Mantissa, MantissaPlane,
    PlaneLayoutError, PlaneLayout,
};
pub use quantize::{floor_log2, quantize_blocks_into, quantize_flat, quantize_tensor, Quantizer};
pub use rounding::{uniform_u01, xorshift_hash, RoundMode};

/// The paper's exponent bitwidth lower bound (§2): 10 bits, range
/// [-512, 511]; fixed across the whole parameter space so mixed-mantissa
/// datapaths share one exponent format.
pub const EXPONENT_BITS: u32 = 10;
pub const EXPONENT_MIN: i32 = -512;
pub const EXPONENT_MAX: i32 = 511;

/// Bits per value for an HBFP(m, b) encoding, amortizing the shared
/// exponent over the block (the §2 "exponent overhead amortization").
pub fn bits_per_value(mantissa_bits: u32, block_size: usize) -> f64 {
    mantissa_bits as f64 + EXPONENT_BITS as f64 / block_size as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponent_amortization() {
        // HBFP4 @ b=64: 4 + 10/64 ≈ 4.156 bits/value.
        let b = bits_per_value(4, 64);
        assert!((b - 4.15625).abs() < 1e-12);
        // Large blocks asymptote to the mantissa width (fixed point).
        assert!(bits_per_value(4, 576) < bits_per_value(4, 16));
    }
}
