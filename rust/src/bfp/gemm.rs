//! Cache-tiled, register-blocked, optionally multi-threaded fixed-point
//! GEMM over packed BFP operands — the production datapath behind
//! [`super::matrix::hbfp_gemm`].
//!
//! # Kernel shape
//!
//! Output is computed in `TILE_J`-wide strips per activation row. For
//! each block along the contraction axis, one activation block is
//! loaded once and MAC'd against four weight blocks at a time (the
//! register-blocked micro-kernel), accumulating in `i32` when both
//! planes are 8-bit (the products fit 2^14, so i32 holds any practical
//! block) and `i64` otherwise. Block sums are combined into the f64
//! accumulator at tile edges via one exact power-of-two scale per block
//! pair.
//!
//! # Thread partitioning rule
//!
//! Work is split over **whole activation rows** into contiguous bands.
//! Bands run as work items on the persistent [`crate::exec`] worker
//! pool (sized by [`crate::util::gemm_thread_budget`]:
//! `BOOSTERS_GEMM_THREADS` override, else `available_parallelism`) —
//! no per-call thread spawn. Each output element is still accumulated
//! by exactly one band job in ascending block order, so the parallel
//! result is bit-identical to the single-threaded one — and both are
//! bit-identical to the scalar [`super::matrix::hbfp_gemm_scalar`]
//! reference, which the property tests enforce.
//!
//! The tiled micro-kernel itself sits behind the [`GemmKernel`] trait
//! ([`ScalarTiledKernel`] is the portable implementation) so a
//! SIMD-explicit kernel can slot in without touching the dispatch,
//! banding, or scheduling layers. Above this module, batch-level
//! consumers enter through the asynchronous
//! [`crate::exec::BfpService`] front door (single-op helpers like
//! [`super::matrix::hbfp_gemm`] ride it via service sessions); this
//! file stays the band-level execution substrate underneath.

use super::block::scale_shift;
use super::matrix::Mat;
use super::packed::{BfpMatrix, Mantissa, MantissaPlane};
use crate::exec::pool::Job;
use anyhow::{bail, Result};

/// Output-strip width of the micro-kernel (f64 accumulators held in
/// registers while one activation block streams the weight plane).
const TILE_J: usize = 8;

/// Below this many MACs, dispatch overhead dominates; stay serial.
/// Shared with the batch scheduler's whole-batch heuristic.
pub(crate) const PARALLEL_MIN_MACS: usize = 1 << 22;

/// Largest block size whose i8 x i8 block MAC provably fits i32
/// (|product| <= 2^14, so 2^16 terms stay under 2^30).
const MAX_I32_BLOCK: usize = 1 << 16;

/// Exact 2^shift in f64. Bit-construction covers the normal range;
/// `powi` handles the subnormal tail identically to the scalar path.
#[inline]
pub(crate) fn exp2_f64(shift: i32) -> f64 {
    if (-1022..=1023).contains(&shift) {
        f64::from_bits(((shift + 1023) as u64) << 52)
    } else {
        (2.0f64).powi(shift)
    }
}

/// Integer MAC over one block pair.
#[inline]
fn dot_block<A: Mantissa, B: Mantissa>(a: &[A], w: &[B]) -> i64 {
    if A::NARROW && B::NARROW && a.len() <= MAX_I32_BLOCK {
        let mut acc = 0i32;
        for (&x, &y) in a.iter().zip(w) {
            acc += x.widen() * y.widen();
        }
        acc as i64
    } else {
        let mut acc = 0i64;
        for (&x, &y) in a.iter().zip(w) {
            acc += x.widen() as i64 * y.widen() as i64;
        }
        acc
    }
}

/// Register-blocked micro-kernel: one activation block against four
/// weight blocks, four accumulators live at once.
#[inline]
fn dot_block4<A: Mantissa, B: Mantissa>(
    a: &[A],
    w0: &[B],
    w1: &[B],
    w2: &[B],
    w3: &[B],
) -> [i64; 4] {
    let n = a.len();
    let (w0, w1, w2, w3) = (&w0[..n], &w1[..n], &w2[..n], &w3[..n]);
    if A::NARROW && B::NARROW && n <= MAX_I32_BLOCK {
        let (mut c0, mut c1, mut c2, mut c3) = (0i32, 0i32, 0i32, 0i32);
        for i in 0..n {
            let x = a[i].widen();
            c0 += x * w0[i].widen();
            c1 += x * w1[i].widen();
            c2 += x * w2[i].widen();
            c3 += x * w3[i].widen();
        }
        [c0 as i64, c1 as i64, c2 as i64, c3 as i64]
    } else {
        let (mut c0, mut c1, mut c2, mut c3) = (0i64, 0i64, 0i64, 0i64);
        for i in 0..n {
            let x = a[i].widen() as i64;
            c0 += x * w0[i].widen() as i64;
            c1 += x * w1[i].widen() as i64;
            c2 += x * w2[i].widen() as i64;
            c3 += x * w3[i].widen() as i64;
        }
        [c0, c1, c2, c3]
    }
}

/// One contiguous band of activation rows (`r0 .. r0 + band_rows`).
#[allow(clippy::too_many_arguments)]
fn gemm_band<A: Mantissa, B: Mantissa>(
    xm: &[A],
    wm: &[B],
    xsh: &[i32],
    wsh: &[i32],
    r0: usize,
    band_rows: usize,
    n: usize,
    kb: usize,
    b: usize,
    out: &mut [f32],
) {
    let stride = kb * b;
    let mut acc = [0.0f64; TILE_J];
    for i in 0..band_rows {
        let gi = r0 + i;
        let xrow = &xm[gi * stride..(gi + 1) * stride];
        let xs = &xsh[gi * kb..(gi + 1) * kb];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let tj = TILE_J.min(n - j0);
            acc[..tj].fill(0.0);
            for k in 0..kb {
                let a = &xrow[k * b..(k + 1) * b];
                let sx = xs[k];
                let mut jj = 0;
                while jj + 4 <= tj {
                    let j = j0 + jj;
                    let o0 = j * stride + k * b;
                    let (o1, o2, o3) = (o0 + stride, o0 + 2 * stride, o0 + 3 * stride);
                    let macs = dot_block4(
                        a,
                        &wm[o0..o0 + b],
                        &wm[o1..o1 + b],
                        &wm[o2..o2 + b],
                        &wm[o3..o3 + b],
                    );
                    for (q, &mac) in macs.iter().enumerate() {
                        if mac != 0 {
                            acc[jj + q] += mac as f64 * exp2_f64(sx + wsh[(j + q) * kb + k]);
                        }
                    }
                    jj += 4;
                }
                while jj < tj {
                    let j = j0 + jj;
                    let mac = dot_block(a, &wm[j * stride + k * b..j * stride + (k + 1) * b]);
                    if mac != 0 {
                        acc[jj] += mac as f64 * exp2_f64(sx + wsh[j * kb + k]);
                    }
                    jj += 1;
                }
            }
            for (jj, &v) in acc[..tj].iter().enumerate() {
                orow[j0 + jj] = v as f32;
            }
            j0 += tj;
        }
    }
}

/// One contiguous band of a GEMM: activation rows `r0 .. r0 + rows` of
/// `x` against every packed column of `w`, writing the band's slice of
/// the output. `xsh`/`wsh` are the precomputed per-block scale shifts
/// ([`band_shifts`]) of the full operands.
pub struct BandTask<'a> {
    pub x: &'a BfpMatrix,
    pub w: &'a BfpMatrix,
    pub xsh: &'a [i32],
    pub wsh: &'a [i32],
    pub r0: usize,
    pub rows: usize,
    pub out: &'a mut [f32],
}

/// A band-level GEMM micro-kernel. Implementations must be pure
/// functions of the task (no scheduling decisions) and must accumulate
/// each output element's blocks in ascending contraction order so that
/// every kernel is bit-compatible with the scalar reference. A
/// SIMD-explicit kernel slots in by implementing this trait.
pub trait GemmKernel: Send + Sync {
    fn name(&self) -> &'static str;
    fn run_band(&self, task: BandTask<'_>);
}

/// The portable cache-tiled, register-blocked kernel (see module docs).
pub struct ScalarTiledKernel;

impl GemmKernel for ScalarTiledKernel {
    fn name(&self) -> &'static str {
        "scalar-tiled"
    }

    fn run_band(&self, t: BandTask<'_>) {
        let n = t.w.rows;
        let kb = t.x.blocks_per_row;
        let b = t.x.fmt.block_size;
        debug_assert_eq!(kb, t.w.blocks_per_row);
        match (&t.x.mantissas, &t.w.mantissas) {
            (MantissaPlane::I8(a), MantissaPlane::I8(w)) => {
                gemm_band(a, w, t.xsh, t.wsh, t.r0, t.rows, n, kb, b, t.out)
            }
            (MantissaPlane::I8(a), MantissaPlane::I16(w)) => {
                gemm_band(a, w, t.xsh, t.wsh, t.r0, t.rows, n, kb, b, t.out)
            }
            (MantissaPlane::I16(a), MantissaPlane::I8(w)) => {
                gemm_band(a, w, t.xsh, t.wsh, t.r0, t.rows, n, kb, b, t.out)
            }
            (MantissaPlane::I16(a), MantissaPlane::I16(w)) => {
                gemm_band(a, w, t.xsh, t.wsh, t.r0, t.rows, n, kb, b, t.out)
            }
        }
    }
}

static SCALAR_KERNEL: ScalarTiledKernel = ScalarTiledKernel;

/// The kernel the runtime currently dispatches to. One home, so a
/// future SIMD kernel (or per-arch selection) swaps in here.
pub fn active_kernel() -> &'static dyn GemmKernel {
    &SCALAR_KERNEL
}

/// Per-block decode scale shifts of a packed operand — hoisted out of
/// the band loop and shared between the single-op path and the batch
/// scheduler.
pub(crate) fn band_shifts(m: &BfpMatrix) -> Vec<i32> {
    m.exponents
        .iter()
        .map(|&e| scale_shift(e, m.fmt.mantissa_bits))
        .collect()
}

/// Band count for an `rows x cols` output with `k` MACs per element.
fn gemm_threads(rows: usize, cols: usize, k: usize) -> usize {
    let macs = rows.saturating_mul(cols).saturating_mul(k);
    if macs < PARALLEL_MIN_MACS || rows < 2 {
        return 1;
    }
    crate::util::gemm_thread_budget().min(rows).min(16)
}

/// `x (m x K)` times the matrix whose columns `rhs_t` packs
/// (`rhs_t.rows = n` columns over `K`), producing `m x n`. Mantissa
/// widths may differ between the operands (the bit-sliced
/// mixed-precision case); block sizes must match.
pub fn gemm_packed(x: &BfpMatrix, rhs_t: &BfpMatrix) -> Result<Mat> {
    gemm_packed_with(x, rhs_t, active_kernel(), None)
}

/// [`gemm_packed`] with an explicit kernel and band-count override
/// (`None` = auto: size heuristic + pool budget). Bands execute on the
/// persistent [`crate::exec`] pool; any band count is bit-identical.
pub(crate) fn gemm_packed_with(
    x: &BfpMatrix,
    rhs_t: &BfpMatrix,
    kernel: &dyn GemmKernel,
    threads: Option<usize>,
) -> Result<Mat> {
    if x.cols != rhs_t.cols {
        bail!("contraction dims {} vs {}", x.cols, rhs_t.cols);
    }
    if x.fmt.block_size != rhs_t.fmt.block_size {
        bail!(
            "block size mismatch {} vs {}",
            x.fmt.block_size,
            rhs_t.fmt.block_size
        );
    }
    let (m, n) = (x.rows, rhs_t.rows);
    let mut out = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return Ok(out);
    }
    let kb = x.blocks_per_row;
    debug_assert_eq!(kb, rhs_t.blocks_per_row);
    let b = x.fmt.block_size;
    let xsh = band_shifts(x);
    let wsh = band_shifts(rhs_t);
    let threads = threads.unwrap_or_else(|| gemm_threads(m, n, kb * b));
    if threads <= 1 {
        kernel.run_band(BandTask {
            x,
            w: rhs_t,
            xsh: &xsh,
            wsh: &wsh,
            r0: 0,
            rows: m,
            out: &mut out.data,
        });
        return Ok(out);
    }
    let band = m.div_ceil(threads);
    let jobs: Vec<Job> = out
        .data
        .chunks_mut(band * n)
        .enumerate()
        .map(|(t, chunk)| {
            let r0 = t * band;
            let (xsh, wsh) = (xsh.as_slice(), wsh.as_slice());
            Box::new(move || {
                kernel.run_band(BandTask {
                    x,
                    w: rhs_t,
                    xsh,
                    wsh,
                    r0,
                    rows: chunk.len() / n,
                    out: chunk,
                });
            }) as Job
        })
        .collect();
    crate::exec::global().pool().scope_run(jobs);
    Ok(out)
}

/// Flat fixed-point inner product of two identically shaped packed
/// operands: integer MAC per block pair, one exponent add per pair,
/// f64 accumulation across blocks in ascending order — the packed
/// replacement for the scalar `bfp_dot_blocks` loop, bit-identical
/// to it.
pub fn packed_dot(x: &BfpMatrix, y: &BfpMatrix) -> Result<f64> {
    if x.rows != y.rows || x.cols != y.cols {
        bail!(
            "shape mismatch {}x{} vs {}x{}",
            x.rows,
            x.cols,
            y.rows,
            y.cols
        );
    }
    if x.fmt.block_size != y.fmt.block_size {
        bail!(
            "block size mismatch {} vs {}",
            x.fmt.block_size,
            y.fmt.block_size
        );
    }
    let b = x.fmt.block_size;
    let (mx, my) = (x.fmt.mantissa_bits, y.fmt.mantissa_bits);
    Ok(match (&x.mantissas, &y.mantissas) {
        (MantissaPlane::I8(a), MantissaPlane::I8(w)) => {
            dot_typed(a, w, &x.exponents, &y.exponents, mx, my, b)
        }
        (MantissaPlane::I8(a), MantissaPlane::I16(w)) => {
            dot_typed(a, w, &x.exponents, &y.exponents, mx, my, b)
        }
        (MantissaPlane::I16(a), MantissaPlane::I8(w)) => {
            dot_typed(a, w, &x.exponents, &y.exponents, mx, my, b)
        }
        (MantissaPlane::I16(a), MantissaPlane::I16(w)) => {
            dot_typed(a, w, &x.exponents, &y.exponents, mx, my, b)
        }
    })
}

fn dot_typed<A: Mantissa, B: Mantissa>(
    a: &[A],
    w: &[B],
    xe: &[i32],
    ye: &[i32],
    mx: u32,
    my: u32,
    b: usize,
) -> f64 {
    let mut acc = 0.0f64;
    for (bi, (xe, ye)) in xe.iter().zip(ye).enumerate() {
        let mac = dot_block(&a[bi * b..(bi + 1) * b], &w[bi * b..(bi + 1) * b]);
        if mac != 0 {
            acc += mac as f64 * exp2_f64(scale_shift(*xe, mx) + scale_shift(*ye, my));
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::{BlockFormat, Quantizer};
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(1.0)).collect()
    }

    #[test]
    fn exp2_matches_powi_across_the_exponent_budget() {
        // Encoded exponents live in [-512, 511]; pair shifts span about
        // [-1052, 1022], crossing into the subnormal range.
        for shift in (-1060..=1030).step_by(7) {
            assert_eq!(
                exp2_f64(shift).to_bits(),
                (2.0f64).powi(shift).to_bits(),
                "shift {shift}"
            );
        }
    }

    #[test]
    fn gemm_agrees_with_dequant_matmul() {
        let fmt = BlockFormat::new(6, 16).unwrap();
        let q = Quantizer::nearest(6);
        let x = Mat::new(7, 50, randn(350, 1)).unwrap();
        let w = Mat::new(50, 9, randn(450, 2)).unwrap();
        let xp = BfpMatrix::encode(&x.data, 7, 50, fmt, q).unwrap();
        let wp = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
        let got = gemm_packed(&xp, &wp).unwrap();
        let want = xp.to_mat().matmul(&wp.decode_transposed()).unwrap();
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn mixed_width_operands_compose() {
        // HBFP6 activations against HBFP12 weights: i8 x i16 planes.
        let f6 = BlockFormat::new(6, 32).unwrap();
        let f12 = BlockFormat::new(12, 32).unwrap();
        let x = Mat::new(3, 64, randn(192, 3)).unwrap();
        let w = Mat::new(64, 4, randn(256, 4)).unwrap();
        let xp = BfpMatrix::encode(&x.data, 3, 64, f6, Quantizer::nearest(6)).unwrap();
        let wp = BfpMatrix::encode_transposed(&w, f12, Quantizer::nearest(12)).unwrap();
        let got = gemm_packed(&xp, &wp).unwrap();
        let want = xp.to_mat().matmul(&wp.decode_transposed()).unwrap();
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn threaded_result_is_bit_identical_to_serial() {
        // Drives the dispatcher with explicit band counts (no env-var
        // mutation, which would race other tests in this binary).
        let fmt = BlockFormat::new(4, 64).unwrap();
        let q = Quantizer::nearest(4);
        let x = Mat::new(96, 640, randn(96 * 640, 5)).unwrap();
        let w = Mat::new(640, 96, randn(640 * 96, 6)).unwrap();
        let xp = BfpMatrix::encode(&x.data, 96, 640, fmt, q).unwrap();
        let wp = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
        // hbfp4 lives on the narrow plane; the typed accessor replaces
        // the old panic-on-mismatch destructure.
        assert!(xp.mantissas.try_i8().is_ok());
        assert!(wp.mantissas.try_i8().is_ok());
        let kernel = active_kernel();
        let serial = gemm_packed_with(&xp, &wp, kernel, Some(1)).unwrap();
        let threaded = gemm_packed_with(&xp, &wp, kernel, Some(4)).unwrap();
        // Uneven band split: 96 rows over 5 bands -> 20,20,20,20,16.
        let uneven = gemm_packed_with(&xp, &wp, kernel, Some(5)).unwrap();
        for ((s, t), u) in serial.data.iter().zip(&threaded.data).zip(&uneven.data) {
            assert_eq!(s.to_bits(), t.to_bits());
            assert_eq!(s.to_bits(), u.to_bits());
        }
        // The public entry agrees with the explicit serial kernel.
        let via_public = gemm_packed(&xp, &wp).unwrap();
        for (s, p) in serial.data.iter().zip(&via_public.data) {
            assert_eq!(s.to_bits(), p.to_bits());
        }
    }

    #[test]
    fn plane_accessor_error_path_is_typed() {
        // The hot path reports dtype mismatches as typed errors instead
        // of panicking (see `MantissaPlane::try_i8`/`try_i16`).
        let f12 = BlockFormat::new(12, 16).unwrap();
        let wide = BfpMatrix::encode(&randn(32, 10), 2, 16, f12, Quantizer::nearest(12)).unwrap();
        assert!(wide.mantissas.try_i16().is_ok());
        let err = wide.mantissas.try_i8().unwrap_err();
        assert_eq!(err.expected, crate::bfp::PlaneDtype::I8);
        assert_eq!(err.found, crate::bfp::PlaneDtype::I16);
        assert!(err.to_string().contains("i16"), "{err}");
        assert!(active_kernel().name().contains("scalar"));
    }

    #[test]
    fn shape_and_block_mismatches_rejected() {
        let f16 = BlockFormat::new(4, 16).unwrap();
        let f64b = BlockFormat::new(4, 64).unwrap();
        let q = Quantizer::nearest(4);
        let a = BfpMatrix::encode(&randn(32, 7), 2, 16, f16, q).unwrap();
        let b = BfpMatrix::encode(&randn(48, 8), 3, 16, f64b, q).unwrap();
        let c = BfpMatrix::encode(&randn(34, 9), 2, 17, f16, q).unwrap();
        assert!(gemm_packed(&a, &b).is_err()); // block size mismatch
        assert!(gemm_packed(&a, &c).is_err()); // contraction mismatch
        assert!(packed_dot(&a, &c).is_err());
    }
}
