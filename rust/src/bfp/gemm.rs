//! Packed-plane GEMM dispatch: banding, threading, and kernel-backend
//! selection over encoded BFP operands — the production datapath behind
//! [`super::matrix::hbfp_gemm`].
//!
//! # Layering
//!
//! Since PR 4 the micro-kernel layer lives in [`super::kernels`] as a
//! registry of interchangeable backends (portable scalar, unrolled
//! autovec, runtime-detected AVX2), every one bit-identical to the
//! scalar reference by construction: backends differ only in their
//! exact integer block dots under one shared cache-tiled band loop.
//! This module is the layer **above** the kernels:
//!
//! * [`gemm_packed`] validates operand geometry, hoists the per-block
//!   decode scale shifts ([`band_shifts`]), picks the kernel for the
//!   operand pair and problem shape via
//!   [`super::kernels::active_kernel`] (dispatch is per
//!   [`super::packed::PlaneLayout`] pair plus an M×N×K bucket when an
//!   autotune table is loaded — nibble-packed 4-bit operands get a
//!   nibble-consuming inner loop, not an unpack pass), and splits the
//!   output over whole activation rows into contiguous bands;
//! * bands run as work items on the persistent [`crate::exec`] worker
//!   pool (sized by [`crate::util::gemm_thread_budget`]) — no per-call
//!   thread spawn. Each output element is accumulated by exactly one
//!   band job in ascending block order, so any band count, any pool
//!   width, and any registered kernel produce results bit-identical to
//!   the scalar [`super::matrix::hbfp_gemm_scalar`] reference — the
//!   property suites pin this per backend.
//!
//! Kernel selection is overridable with `BOOSTERS_KERNEL`
//! (`auto`/`scalar`/`autovec`/`avx2`/`avx512`/`neon`, see
//! [`crate::util::kernel_override`]) and, under `auto`, steered by the
//! host's autotune table (`BOOSTERS_AUTOTUNE`, see
//! [`super::kernels::autotune`]); unsupported requests fall back
//! loudly, never panic, and can never change numerics. Above this
//! module, batch-level consumers enter through the asynchronous
//! [`crate::exec::BfpService`] front door (single-op helpers like
//! [`super::matrix::hbfp_gemm`] ride it via service sessions); this
//! file stays the band-level execution substrate underneath.

use super::block::scale_shift;
use super::kernels::{exp2_f64, with_plane_pair_dot, BlockDot};
use super::matrix::Mat;
use super::packed::BfpMatrix;
use crate::exec::pool::Job;
use anyhow::{bail, Result};

pub use super::kernels::{
    active_kernel, registry, BandTask, GemmKernel, GemmShape, ScalarTiledKernel,
};

/// Below this many MACs, dispatch overhead dominates; stay serial.
/// Shared with the batch scheduler's whole-batch heuristic.
pub(crate) const PARALLEL_MIN_MACS: usize = 1 << 22;

/// Per-block decode scale shifts of a packed operand — hoisted out of
/// the band loop and shared between the single-op path and the batch
/// scheduler.
pub(crate) fn band_shifts(m: &BfpMatrix) -> Vec<i32> {
    let mut out = Vec::with_capacity(m.exponents.len());
    band_shifts_into(m, &mut out);
    out
}

/// [`band_shifts`] into a caller-provided vector — the pipeline's
/// decode stage fills arena-recycled shift planes without reallocating.
/// Same mapping, same order; the vector is cleared first.
pub(crate) fn band_shifts_into(m: &BfpMatrix, out: &mut Vec<i32>) {
    out.clear();
    out.reserve(m.exponents.len());
    for &e in &m.exponents {
        out.push(scale_shift(e, m.fmt.mantissa_bits));
    }
}

/// Band count for an `rows x cols` output with `k` MACs per element.
fn gemm_threads(rows: usize, cols: usize, k: usize) -> usize {
    let macs = rows.saturating_mul(cols).saturating_mul(k);
    if macs < PARALLEL_MIN_MACS || rows < 2 {
        return 1;
    }
    crate::util::gemm_thread_budget().min(rows).min(16)
}

/// `x (m x K)` times the matrix whose columns `rhs_t` packs
/// (`rhs_t.rows = n` columns over `K`), producing `m x n`. Mantissa
/// widths may differ between the operands (the bit-sliced
/// mixed-precision case — including nibble-packed against byte
/// planes); block sizes must match. The kernel backend is chosen per
/// operand-layout pair by the registry.
pub fn gemm_packed(x: &BfpMatrix, rhs_t: &BfpMatrix) -> Result<Mat> {
    // `active_kernel` only returns backends that support the
    // combination (mismatched block sizes error in the inner path).
    let kernel = active_kernel(
        x.mantissas.layout(),
        rhs_t.mantissas.layout(),
        x.fmt.block_size,
        GemmShape::new(x.rows, rhs_t.rows, x.cols),
    );
    gemm_packed_inner(x, rhs_t, kernel, None)
}

/// [`gemm_packed`] with an explicit kernel and band-count override
/// (`None` = auto: size heuristic + pool budget). Bands execute on the
/// persistent [`crate::exec`] pool; any band count and any registered
/// kernel is bit-identical. Public so tests and benches can pin every
/// backend from [`super::kernels::registry`] individually. A kernel
/// that does not support the operands' layout pair degrades down the
/// registry's fallback chain (never panics, never changes bits) —
/// same contract as [`crate::exec::BatchGemm::with_kernel`].
pub fn gemm_packed_with(
    x: &BfpMatrix,
    rhs_t: &BfpMatrix,
    kernel: &'static dyn GemmKernel,
    threads: Option<usize>,
) -> Result<Mat> {
    let kernel = registry().select_from(
        kernel,
        x.mantissas.layout(),
        rhs_t.mantissas.layout(),
        x.fmt.block_size.max(rhs_t.fmt.block_size),
    );
    gemm_packed_inner(x, rhs_t, kernel, threads)
}

fn gemm_packed_inner(
    x: &BfpMatrix,
    rhs_t: &BfpMatrix,
    kernel: &dyn GemmKernel,
    threads: Option<usize>,
) -> Result<Mat> {
    if x.cols != rhs_t.cols {
        bail!("contraction dims {} vs {}", x.cols, rhs_t.cols);
    }
    if x.fmt.block_size != rhs_t.fmt.block_size {
        bail!(
            "block size mismatch {} vs {}",
            x.fmt.block_size,
            rhs_t.fmt.block_size
        );
    }
    let (m, n) = (x.rows, rhs_t.rows);
    let mut out = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return Ok(out);
    }
    let kb = x.blocks_per_row;
    debug_assert_eq!(kb, rhs_t.blocks_per_row);
    let b = x.fmt.block_size;
    let xsh = band_shifts(x);
    let wsh = band_shifts(rhs_t);
    let threads = threads.unwrap_or_else(|| gemm_threads(m, n, kb * b));
    if threads <= 1 {
        kernel.run_band(BandTask {
            x,
            w: rhs_t,
            xsh: &xsh,
            wsh: &wsh,
            r0: 0,
            rows: m,
            out: &mut out.data,
        });
        return Ok(out);
    }
    let band = m.div_ceil(threads);
    let jobs: Vec<Job> = out
        .data
        .chunks_mut(band * n)
        .enumerate()
        .map(|(t, chunk)| {
            let r0 = t * band;
            let (xsh, wsh) = (xsh.as_slice(), wsh.as_slice());
            Box::new(move || {
                kernel.run_band(BandTask {
                    x,
                    w: rhs_t,
                    xsh,
                    wsh,
                    r0,
                    rows: chunk.len() / n,
                    out: chunk,
                });
            }) as Job
        })
        .collect();
    crate::exec::global().pool().scope_run(jobs);
    Ok(out)
}

/// Flat fixed-point inner product of two identically shaped packed
/// operands: integer MAC per block pair, one exponent add per pair,
/// f64 accumulation across blocks in ascending order — the packed
/// replacement for the scalar `bfp_dot_blocks` loop, bit-identical
/// to it for every plane-layout pair (nibble-packed included).
pub fn packed_dot(x: &BfpMatrix, y: &BfpMatrix) -> Result<f64> {
    if x.rows != y.rows || x.cols != y.cols {
        bail!(
            "shape mismatch {}x{} vs {}x{}",
            x.rows,
            x.cols,
            y.rows,
            y.cols
        );
    }
    if x.fmt.block_size != y.fmt.block_size {
        bail!(
            "block size mismatch {} vs {}",
            x.fmt.block_size,
            y.fmt.block_size
        );
    }
    let b = x.fmt.block_size;
    let (mx, my) = (x.fmt.mantissa_bits, y.fmt.mantissa_bits);
    // Plane-view construction (byte/i16 pairs on the zipped-subslice
    // loop, nibble-involved pairs on index-generic access) is
    // single-homed in the kernels' shared macro; each arm is
    // monomorphized — no dyn indirection on the dot hot path, where
    // blocks can be as small as a few MACs.
    Ok(with_plane_pair_dot!(&x.mantissas, &y.mantissas, |d| dot_over(
        &d,
        &x.exponents,
        &y.exponents,
        mx,
        my,
        b
    )))
}

/// Shared blockwise dot-accumulation loop of [`packed_dot`]: exact
/// integer MAC per block pair, one exponent add per pair, f64
/// accumulation in ascending block order.
fn dot_over<D: BlockDot>(d: &D, xe: &[i32], ye: &[i32], mx: u32, my: u32, b: usize) -> f64 {
    let mut acc = 0.0f64;
    for (bi, (xe, ye)) in xe.iter().zip(ye).enumerate() {
        let mac = d.dot(bi * b, bi * b, b);
        if mac != 0 {
            acc += mac as f64 * exp2_f64(scale_shift(*xe, mx) + scale_shift(*ye, my));
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::{BlockFormat, PlaneLayout, Quantizer};
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(1.0)).collect()
    }

    #[test]
    fn gemm_agrees_with_dequant_matmul() {
        let fmt = BlockFormat::new(6, 16).unwrap();
        let q = Quantizer::nearest(6);
        let x = Mat::new(7, 50, randn(350, 1)).unwrap();
        let w = Mat::new(50, 9, randn(450, 2)).unwrap();
        let xp = BfpMatrix::encode(&x.data, 7, 50, fmt, q).unwrap();
        let wp = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
        let got = gemm_packed(&xp, &wp).unwrap();
        let want = xp.to_mat().matmul(&wp.decode_transposed()).unwrap();
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn mixed_width_operands_compose() {
        // HBFP4 activations against HBFP12 weights: nibble x i16
        // planes — the widest layout gap the dispatch must bridge.
        let f4 = BlockFormat::new(4, 32).unwrap();
        let f12 = BlockFormat::new(12, 32).unwrap();
        let x = Mat::new(3, 64, randn(192, 3)).unwrap();
        let w = Mat::new(64, 4, randn(256, 4)).unwrap();
        let xp = BfpMatrix::encode(&x.data, 3, 64, f4, Quantizer::nearest(4)).unwrap();
        let wp = BfpMatrix::encode_transposed(&w, f12, Quantizer::nearest(12)).unwrap();
        assert_eq!(xp.mantissas.layout(), PlaneLayout::I4Packed);
        assert_eq!(wp.mantissas.layout(), PlaneLayout::I16);
        let got = gemm_packed(&xp, &wp).unwrap();
        let want = xp.to_mat().matmul(&wp.decode_transposed()).unwrap();
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn threaded_result_is_bit_identical_to_serial_for_every_kernel() {
        // Drives the dispatcher with explicit band counts (no env-var
        // mutation, which would race other tests in this binary).
        let fmt = BlockFormat::new(4, 64).unwrap();
        let q = Quantizer::nearest(4);
        let x = Mat::new(96, 640, randn(96 * 640, 5)).unwrap();
        let w = Mat::new(640, 96, randn(640 * 96, 6)).unwrap();
        let xp = BfpMatrix::encode(&x.data, 96, 640, fmt, q).unwrap();
        let wp = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
        // hbfp4 with an even block lives on the nibble-packed plane.
        assert!(xp.mantissas.try_i4().is_ok());
        assert!(wp.mantissas.try_i4().is_ok());
        let reference = gemm_packed_with(&xp, &wp, &ScalarTiledKernel, Some(1)).unwrap();
        for kernel in registry().all() {
            let serial = gemm_packed_with(&xp, &wp, *kernel, Some(1)).unwrap();
            let threaded = gemm_packed_with(&xp, &wp, *kernel, Some(4)).unwrap();
            // Uneven band split: 96 rows over 5 bands -> 20,20,20,20,16.
            let uneven = gemm_packed_with(&xp, &wp, *kernel, Some(5)).unwrap();
            for ((s, t), u) in serial.data.iter().zip(&threaded.data).zip(&uneven.data) {
                assert_eq!(s.to_bits(), t.to_bits(), "kernel {}", kernel.name());
                assert_eq!(s.to_bits(), u.to_bits(), "kernel {}", kernel.name());
            }
            for (s, r) in serial.data.iter().zip(&reference.data) {
                assert_eq!(s.to_bits(), r.to_bits(), "kernel {}", kernel.name());
            }
        }
        // The public entry agrees with the explicit serial reference.
        let via_public = gemm_packed(&xp, &wp).unwrap();
        for (s, p) in reference.data.iter().zip(&via_public.data) {
            assert_eq!(s.to_bits(), p.to_bits());
        }
    }

    #[test]
    fn plane_accessor_error_path_is_typed() {
        // The hot path reports layout mismatches as typed errors
        // instead of panicking (see `MantissaPlane::try_i8`/`try_i16`).
        let f12 = BlockFormat::new(12, 16).unwrap();
        let wide = BfpMatrix::encode(&randn(32, 10), 2, 16, f12, Quantizer::nearest(12)).unwrap();
        assert!(wide.mantissas.try_i16().is_ok());
        let err = wide.mantissas.try_i8().unwrap_err();
        assert_eq!(err.expected, PlaneLayout::I8);
        assert_eq!(err.found, PlaneLayout::I16);
        assert!(err.to_string().contains("i16"), "{err}");
        // Wide planes always dispatch to the scalar backend — the only
        // kernel that supports them.
        let k = active_kernel(PlaneLayout::I16, PlaneLayout::I16, 16, GemmShape::new(2, 2, 16));
        assert!(k.name().contains("scalar"), "{}", k.name());
    }

    #[test]
    fn shape_and_block_mismatches_rejected() {
        let f16 = BlockFormat::new(4, 16).unwrap();
        let f64b = BlockFormat::new(4, 64).unwrap();
        let q = Quantizer::nearest(4);
        let a = BfpMatrix::encode(&randn(32, 7), 2, 16, f16, q).unwrap();
        let b = BfpMatrix::encode(&randn(48, 8), 3, 16, f64b, q).unwrap();
        let c = BfpMatrix::encode(&randn(34, 9), 2, 17, f16, q).unwrap();
        assert!(gemm_packed(&a, &b).is_err()); // block size mismatch
        assert!(gemm_packed(&a, &c).is_err()); // contraction mismatch
        assert!(packed_dot(&a, &c).is_err());
    }

    #[test]
    fn packed_dot_agrees_across_layout_pairs() {
        // Nibble x nibble, nibble x i8, and nibble x i16 dots all go
        // through the same access-generic block dot; cross-check each
        // against the dequantized f64 dot.
        let n = 200usize;
        let x = randn(n, 21);
        let y = randn(n, 22);
        for (mx, my) in [(4u32, 4u32), (4, 6), (6, 4), (3, 12)] {
            let fx = BlockFormat::new(mx, 32).unwrap();
            let fy = BlockFormat::new(my, 32).unwrap();
            let xp = BfpMatrix::encode(&x, 1, n, fx, Quantizer::nearest(mx)).unwrap();
            let yp = BfpMatrix::encode(&y, 1, n, fy, Quantizer::nearest(my)).unwrap();
            let got = packed_dot(&xp, &yp).unwrap();
            let want: f64 = xp
                .to_mat()
                .data
                .iter()
                .zip(&yp.to_mat().data)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "mx={mx} my={my}: {got} vs {want}"
            );
        }
    }
}
