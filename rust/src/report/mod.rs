//! Paper-layout rendering of tables and figure data (ASCII to stdout,
//! CSV to `results/`).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned ASCII table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncols {
                let _ = write!(out, "| {:w$} ", cells[i], w = widths[i]);
            }
            let _ = writeln!(out, "|");
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write as CSV under `results/`.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// results/ directory helper.
pub fn results_dir() -> std::path::PathBuf {
    std::env::var_os("REPRO_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("results"))
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

pub fn fmt_x(x: f64) -> String {
    format!("{x:.1}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("T", &["fmt", "acc"]);
        t.row(vec!["fp32".into(), "91.72".into()]);
        t.row(vec!["hbfp4".into(), "80.18".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| fp32  |"));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_write() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("boosters_test_csv2");
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_pct(0.9172), "91.72");
        assert_eq!(fmt_x(21.34), "21.3x");
    }
}
