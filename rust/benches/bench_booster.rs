//! Table-2 / Fig-3 bench: the Accuracy-Booster scheduler itself (pure L3
//! logic, should be ~free) and the cost of the precision *switch* — the
//! same executable serving HBFP4 and HBFP6 steps back to back, which is
//! the paper's bit-sliced-datapath story in software form.

use boosters::analysis::quantize_params_packed_cached;
use boosters::bfp::{BfpMatrix, Quantizer};
use boosters::config::PrecisionPolicy;
use boosters::runtime::Tensor;
use boosters::coordinator::{init_state, AutoBoost, PrecisionScheduler, TrainerData};
use boosters::experiments::common::config_for;
use boosters::experiments::Preset;
use boosters::runtime::{artifacts_dir, Engine};
use boosters::util::bench::BenchSuite;
use boosters::util::Rng;

fn main() {
    let mut suite = BenchSuite::new("booster: scheduler + precision switching");

    // Pure scheduler decisions: millions/sec expected.
    let sched = PrecisionScheduler::new(PrecisionPolicy::booster(1), 160, true);
    suite.bench_items("scalars_at x 160 epochs x 100 steps", Some(16_000.0), || {
        let mut acc = 0.0f32;
        for e in 0..160 {
            for s in 0..100 {
                acc += sched.scalars_at(e, s).bits_mid;
            }
        }
        std::hint::black_box(acc);
    });

    // Host-side packed-BFP weight store: the per-epoch cost the Trainer
    // pays in `with_host_bfp_store` mode, at both precisions an
    // AutoBoost/Booster run flips between. 1M params ≈ the CNN.
    let mut rng = Rng::new(0xB00);
    let mut weights: Vec<f32> = (0..1 << 20).map(|_| rng.normal_scaled(0.1)).collect();
    let mut scratch = BfpMatrix::empty();
    let mut buf: Vec<f32> = Vec::new();
    let mut ab = AutoBoost::new(4, 6);
    for boosted in [false, true] {
        if boosted {
            // Flatline losses trip the plateau trigger.
            for e in 0..12 {
                ab.observe(e, 1.0);
            }
            assert!(ab.boosted());
        }
        let fmt = ab.emulation_format(64).unwrap();
        let m = fmt.mantissa_bits;
        suite.bench_items(
            &format!("host BFP weight-store round-trip m={m} b=64 (1M params)"),
            Some(weights.len() as f64),
            || {
                scratch
                    .encode_into(&weights, 1, weights.len(), fmt, Quantizer::nearest(m), 0)
                    .unwrap();
                scratch.decode_into(&mut buf);
                weights.copy_from_slice(&buf);
                std::hint::black_box(weights.len());
            },
        );
    }

    // The exec-cached weight store: a frozen parameter tensor (content
    // unchanged across epochs) is served from the operand cache instead
    // of re-encoding — the Trainer emulation-loop fast path.
    let rt = boosters::exec::global();
    let frozen: Vec<f32> = {
        let mut r = Rng::new(0xF60);
        (0..1 << 18).map(|_| r.normal_scaled(0.1)).collect()
    };
    let mut qbuf: Vec<f32> = Vec::new();
    suite.bench_items(
        "host BFP store via exec cache, frozen tensor (256k params)",
        Some(frozen.len() as f64),
        || {
            let mut params = vec![Tensor::from_f32(&[frozen.len()], frozen.clone()).unwrap()];
            quantize_params_packed_cached(&mut params, 4, 64, rt, &mut qbuf).unwrap();
            std::hint::black_box(params.len());
        },
    );
    println!("### exec cache after store benches: {}", rt.cache_stats().summary());

    let artifacts = artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("### runtime part skipped: artifacts/ missing");
        suite.finish();
        return;
    }
    let engine = Engine::new().expect("pjrt client");
    let v = engine
        .load_variant_by_name(&artifacts, "cnn_bs64")
        .expect("cnn_bs64");
    let cfg = config_for(&v, PrecisionPolicy::booster(1), Preset::Quick);
    let data = TrainerData::for_variant(&v, &cfg).expect("data");
    let mut state = init_state(&v.manifest, 1).expect("init");
    let idx: Vec<usize> = (0..v.manifest.batch).collect();
    let (x, y) = data.batch(&idx, false);

    // Alternate 4-bit / 6-bit steps on the SAME executable: no recompile,
    // no cache miss — the runtime-scalar design at work.
    let s4 = sched.scalars_at(0, 0);
    let s6 = sched.scalars_at(159, 0);
    assert_eq!(s4.bits_mid, 4.0);
    assert_eq!(s6.bits_mid, 6.0);
    suite.bench_items(
        "alternating hbfp4/hbfp6 train_step pair",
        Some(2.0 * v.manifest.batch as f64),
        || {
            std::hint::black_box(engine.train_step(&v, &mut state, &x, &y, s4, 0.01).unwrap());
            std::hint::black_box(engine.train_step(&v, &mut state, &x, &y, s6, 0.01).unwrap());
        },
    );
    suite.finish();
}
