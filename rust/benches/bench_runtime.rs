//! Runtime-layer benches: artifact compile time, single train-step and
//! eval latency per model family — the end-to-end L3 hot loop that every
//! table's wall-clock is made of. Requires `make artifacts`.

use boosters::config::PrecisionPolicy;
use boosters::coordinator::{init_state, TrainerData};
use boosters::experiments::common::config_for;
use boosters::experiments::Preset;
use boosters::runtime::{artifacts_dir, Engine, StepScalars};
use boosters::util::bench::BenchSuite;

fn main() {
    let artifacts = artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("### bench skipped: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::new().expect("pjrt client");
    let mut suite = BenchSuite::new("runtime: AOT step latency");

    for name in ["mlp_bs64", "mlp_bs64_pallas", "cnn_bs64", "transformer_bs64"] {
        let v = match engine.load_variant_by_name(&artifacts, name) {
            Ok(v) => v,
            Err(_) => continue,
        };
        let cfg = config_for(&v, PrecisionPolicy::booster(1), Preset::Quick);
        let data = TrainerData::for_variant(&v, &cfg).expect("data");
        let mut state = init_state(&v.manifest, 42).expect("init");
        let idx: Vec<usize> = (0..v.manifest.batch).collect();
        let (x, y) = data.batch(&idx, false);
        let sc = StepScalars::hbfp(4.0);
        let items = Some(v.manifest.batch as f64);

        suite.bench_items(&format!("{name} train_step (batch)"), items, || {
            std::hint::black_box(
                engine.train_step(&v, &mut state, &x, &y, sc, 0.01).unwrap(),
            );
        });
        suite.bench_items(&format!("{name} eval_batch"), items, || {
            std::hint::black_box(engine.eval_batch(&v, &state, &x, &y, sc).unwrap());
        });
        // FP32-bypass step for the emulation-overhead ratio (paper: HBFP
        // emulation ≈ 1.5x FP32 wall-clock on GPU).
        let sc32 = StepScalars::fp32();
        suite.bench_items(&format!("{name} train_step fp32-bypass"), items, || {
            std::hint::black_box(
                engine.train_step(&v, &mut state, &x, &y, sc32, 0.01).unwrap(),
            );
        });
    }
    suite.finish();
}
