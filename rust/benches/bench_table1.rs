//! Table-1 bench: times the building blocks of the standalone-HBFP sweep
//! rather than the full multi-minute sweep — `repro table1` regenerates
//! the actual table; this bench tracks the per-cell cost that the
//! sweep's wall-clock is made of.
//!
//! Two sections:
//! 1. host-side packed tensor-engine proxy (always runs): the 512^3
//!    HBFP4 GEMM a table cell's layers amount to, scalar reference vs
//!    packed kernel — the >= 4x acceptance gate of the BfpMatrix
//!    refactor;
//! 2. compiled train-step cost per (format, block) cell (requires
//!    `make artifacts`).

use boosters::bfp::{hbfp_gemm, hbfp_gemm_scalar, BfpMatrix, BlockFormat, Mat, Quantizer};
use boosters::config::PrecisionPolicy;
use boosters::coordinator::{init_state, PrecisionScheduler, TrainerData};
use boosters::experiments::common::config_for;
use boosters::experiments::Preset;
use boosters::runtime::{artifacts_dir, Engine};
use boosters::util::bench::BenchSuite;
use boosters::util::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal_scaled(1.0)).collect()
}

fn main() {
    let mut suite = BenchSuite::new("table1: packed GEMM proxy + per-cell step cost");

    // --- 1. host tensor-engine proxy (no artifacts needed) -------------
    let dim = 512usize;
    let macs = (dim * dim * dim) as f64;
    let x = Mat::new(dim, dim, randn(dim * dim, 1)).unwrap();
    let w = Mat::new(dim, dim, randn(dim * dim, 2)).unwrap();
    let fmt = BlockFormat::new(4, 64).unwrap();
    suite.bench_items("cell GEMM 512^3 hbfp4 SCALAR (MACs)", Some(macs), || {
        std::hint::black_box(hbfp_gemm_scalar(&x, &w, fmt).unwrap());
    });
    suite.bench_items("cell GEMM 512^3 hbfp4 PACKED (MACs)", Some(macs), || {
        std::hint::black_box(hbfp_gemm(&x, &w, fmt).unwrap());
    });
    let q = Quantizer::nearest(4);
    let xp = BfpMatrix::encode(&x.data, dim, dim, fmt, q).unwrap();
    let wp = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
    suite.bench_items(
        "cell GEMM 512^3 hbfp4 PACKED pre-encoded (MACs)",
        Some(macs),
        || {
            std::hint::black_box(xp.gemm(&wp).unwrap());
        },
    );
    // The paper's extreme block size exercises the long-block kernel.
    let f576 = BlockFormat::new(4, 576).unwrap();
    suite.bench_items("cell GEMM 512^3 hbfp4 b=576 PACKED (MACs)", Some(macs), || {
        std::hint::black_box(hbfp_gemm(&x, &w, f576).unwrap());
    });

    // --- 2. compiled per-cell step cost (artifact-gated) ---------------
    let artifacts = artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("### train-step section skipped: artifacts/ missing (run `make artifacts`)");
        suite.finish();
        return;
    }
    let engine = Engine::new().expect("pjrt client");

    for block in [16usize, 64, 576] {
        let v = match engine.load_variant_by_name(&artifacts, &format!("cnn_bs{block}")) {
            Ok(v) => v,
            Err(_) => continue,
        };
        let cfg = config_for(&v, PrecisionPolicy::Hbfp { bits: 4 }, Preset::Quick);
        let data = TrainerData::for_variant(&v, &cfg).expect("data");
        let mut state = init_state(&v.manifest, 1).expect("init");
        let idx: Vec<usize> = (0..v.manifest.batch).collect();
        let (x, y) = data.batch(&idx, false);
        for bits in [8.0f32, 6.0, 5.0, 4.0] {
            let sched = PrecisionScheduler::new(
                PrecisionPolicy::Hbfp { bits: bits as u32 },
                8,
                true,
            );
            let sc = sched.scalars_at(0, 0);
            suite.bench_items(
                &format!("cnn b={block} hbfp{bits} train_step"),
                Some(v.manifest.batch as f64),
                || {
                    std::hint::black_box(
                        engine.train_step(&v, &mut state, &x, &y, sc, 0.01).unwrap(),
                    );
                },
            );
        }
    }
    suite.finish();
}
