//! Table-1 bench: times the building blocks of the standalone-HBFP sweep
//! (one training step per (format, block) cell) rather than the full
//! multi-minute sweep — `repro table1` regenerates the actual table; this
//! bench tracks the per-cell cost that the sweep's wall-clock is made of.

use boosters::config::PrecisionPolicy;
use boosters::coordinator::{init_state, PrecisionScheduler, TrainerData};
use boosters::experiments::common::config_for;
use boosters::experiments::Preset;
use boosters::runtime::{artifacts_dir, Engine};
use boosters::util::bench::BenchSuite;

fn main() {
    let artifacts = artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("### bench skipped: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::new().expect("pjrt client");
    let mut suite = BenchSuite::new("table1: per-cell step cost (cnn)");

    for block in [16usize, 64, 576] {
        let v = match engine.load_variant_by_name(&artifacts, &format!("cnn_bs{block}")) {
            Ok(v) => v,
            Err(_) => continue,
        };
        let cfg = config_for(&v, PrecisionPolicy::Hbfp { bits: 4 }, Preset::Quick);
        let data = TrainerData::for_variant(&v, &cfg).expect("data");
        let mut state = init_state(&v.manifest, 1).expect("init");
        let idx: Vec<usize> = (0..v.manifest.batch).collect();
        let (x, y) = data.batch(&idx, false);
        for bits in [8.0f32, 6.0, 5.0, 4.0] {
            let sched = PrecisionScheduler::new(
                PrecisionPolicy::Hbfp { bits: bits as u32 },
                8,
                true,
            );
            let sc = sched.scalars_at(0, 0);
            suite.bench_items(
                &format!("cnn b={block} hbfp{bits} train_step"),
                Some(v.manifest.batch as f64),
                || {
                    std::hint::black_box(
                        engine.train_step(&v, &mut state, &x, &y, sc, 0.01).unwrap(),
                    );
                },
            );
        }
    }
    suite.finish();
}
