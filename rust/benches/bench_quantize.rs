//! Hot-path microbenches for the rust BFP substrate: the quantizer (the
//! L3 analogue of the L1 Pallas kernel), packing, fixed-point dots, and
//! the packed-vs-scalar GEMM comparison that gates the tensor-engine
//! refactor (>= 4x on a 512^3 HBFP4 GEMM). This is the §Perf L3 surface
//! — before/after numbers live in EXPERIMENTS.md.
//!
//! `-- --autotune [PATH]` runs the **autotune pass** instead of the
//! suite: every registered kernel backend is timed on one
//! representative GEMM per (plane-layout pair, block bucket, M×N×K
//! bucket), and the fastest backend per bucket is written as the
//! `boosters-autotune-v1` table (default `artifacts/autotune.json`)
//! that the kernel registry's shape-aware dispatch loads at startup.

use boosters::bfp::kernels::TableBuilder;
use boosters::bfp::{
    bfp_dot_fixed_point, gemm_packed_with, hbfp_gemm, hbfp_gemm_scalar, quantize_flat,
    quantize_packed_into, registry, AutotuneTable, BfpMatrix, BfpTensor, BlockFormat, Mat,
    Quantizer,
};
use boosters::exec::{BatchGemm, GemmRequest, OwnedGemmOp};
use boosters::util::bench::{bench_fn, BenchSuite};
use boosters::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal_scaled(1.0)).collect()
}

/// `--autotune [PATH]` / `--autotune=PATH` from argv (scanned manually:
/// cargo prepends its own flags to harness-false bench binaries). The
/// path defaults to the registry's primary probe location.
fn autotune_sink() -> Option<PathBuf> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--autotune" {
            return Some(
                args.next()
                    .filter(|p| !p.starts_with("--"))
                    .map(PathBuf::from)
                    .unwrap_or_else(|| PathBuf::from("artifacts/autotune.json")),
            );
        }
        if let Some(rest) = a.strip_prefix("--autotune=") {
            return Some(PathBuf::from(rest));
        }
    }
    None
}

/// Time every registered backend on one representative shape per
/// dispatch bucket and persist the fastest-per-bucket table. Bucket
/// coverage: blocks 16 (`b16`) and 64 (`b64`) x shapes 48^3 (`small`),
/// 96^3 (`medium`), 320^3 (`large`); `bwide` blocks always run scalar
/// (i32-overflow gate), so tuning them buys nothing.
fn run_autotune(path: &std::path::Path) {
    let budget_ms = std::env::var("REPRO_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120.0);
    println!("### autotune pass: registered kernels per (layout pair, block, shape) bucket");
    let shapes = [(48usize, 48usize, 48usize), (96, 96, 96), (320, 320, 320)];
    let fmts = [
        BlockFormat::new(4, 64).unwrap(),
        BlockFormat::new(6, 64).unwrap(),
        BlockFormat::new(4, 16).unwrap(),
    ];
    let mut builder = TableBuilder::new();
    for fmt in fmts {
        let q = Quantizer::nearest(fmt.mantissa_bits);
        let layout = fmt.plane_layout();
        for (m, n, k) in shapes {
            let xp = BfpMatrix::encode(&randn(m * k, 11), m, k, fmt, q).unwrap();
            let wm = Mat::new(k, n, randn(k * n, 13)).unwrap();
            let wp = BfpMatrix::encode_transposed(&wm, fmt, q).unwrap();
            for kernel in registry().all() {
                if !kernel.supports(layout, layout, fmt.block_size) {
                    continue;
                }
                let r = bench_fn(
                    &format!(
                        "{m}x{n}x{k} m={} b={} kernel={}",
                        fmt.mantissa_bits,
                        fmt.block_size,
                        kernel.name()
                    ),
                    budget_ms,
                    Some((m * n * k) as f64),
                    || {
                        std::hint::black_box(gemm_packed_with(&xp, &wp, *kernel, None).unwrap());
                    },
                );
                println!("{}", r.report());
                builder.record(layout, layout, fmt.block_size, (m, n, k), kernel.name(), r.mean_ns);
            }
        }
    }
    let mut text = builder.to_json().render();
    text.push('\n');
    // Round-trip through the loader before writing: an artifact the
    // registry cannot parse must fail the pass, not poison startup.
    let table = AutotuneTable::parse(&text).expect("autotune artifact must parse");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).expect("create autotune artifact dir");
        }
    }
    std::fs::write(path, &text).expect("write autotune artifact");
    println!("### autotune: wrote {} bucket entries to {}", table.len(), path.display());
}

fn main() {
    if let Some(path) = autotune_sink() {
        run_autotune(&path);
        return;
    }
    let mut suite = BenchSuite::new("bfp quantizer + packed tensor engine hot path");
    let x = randn(1 << 20, 1); // 1M elements ≈ a large conv layer
    let n = x.len() as f64;

    for (m, b) in [(4u32, 64usize), (6, 64), (4, 576), (8, 16)] {
        let q = Quantizer::nearest(m);
        suite.bench_items(&format!("quantize_flat m={m} b={b} (1M f32)"), Some(n), || {
            std::hint::black_box(quantize_flat(&x, b, q, 0));
        });
    }
    let qs = Quantizer::stochastic(4, 7);
    suite.bench_items("quantize_flat m=4 b=64 stochastic (1M f32)", Some(n), || {
        std::hint::black_box(quantize_flat(&x, 64, qs, 0));
    });

    // Packed carrier: encode/decode into reused planes (zero steady-state
    // allocation) vs the per-block BfpTensor objects.
    let fmt = BlockFormat::new(4, 64).unwrap();
    let q4 = Quantizer::nearest(4);
    suite.bench_items("BfpTensor::encode m=4 b=64 (1M f32)", Some(n), || {
        std::hint::black_box(BfpTensor::encode(&x, fmt).unwrap());
    });
    let enc = BfpTensor::encode(&x, fmt).unwrap();
    suite.bench_items("BfpTensor::decode m=4 b=64 (1M f32)", Some(n), || {
        std::hint::black_box(enc.decode());
    });
    let mut packed = BfpMatrix::empty();
    suite.bench_items("BfpMatrix::encode_into m=4 b=64 (1M f32)", Some(n), || {
        packed.encode_into(&x, 1, x.len(), fmt, q4, 0).unwrap();
        std::hint::black_box(packed.storage_bits());
    });
    let mut dec = Vec::new();
    suite.bench_items("BfpMatrix::decode_into m=4 b=64 (1M f32)", Some(n), || {
        packed.decode_into(&mut dec);
        std::hint::black_box(dec.len());
    });
    let mut qout = Vec::new();
    suite.bench_items(
        "quantize_packed_into m=4 b=64 reused bufs (1M f32)",
        Some(n),
        || {
            quantize_packed_into(&x, 64, q4, 0, &mut packed, &mut qout).unwrap();
            std::hint::black_box(qout.len());
        },
    );

    // --- nibble-direct encode fast path ---------------------------------
    // The unified block-writer core quantizes m=4 operands straight
    // into packed nibble bytes (no i8 scratch round-trip). Same data,
    // same shapes, m=4 (nibble-direct writer) vs m=6 (i8 writer): the
    // series pair measures the fast path's win instead of asserting it.
    // Multi-row shape -> the row-band pool split; the transposed pair
    // covers the column-gather split.
    let fmt6 = BlockFormat::new(6, 64).unwrap();
    let q6 = Quantizer::nearest(6);
    let mut enc4 = BfpMatrix::empty();
    let mut enc6 = BfpMatrix::empty();
    suite.bench_items(
        "encode_into 1024x1024 m=4 b=64 nibble-direct (f32)",
        Some(n),
        || {
            enc4.encode_into(&x, 1024, 1024, fmt, q4, 0).unwrap();
            std::hint::black_box(enc4.storage_bits());
        },
    );
    suite.bench_items(
        "encode_into 1024x1024 m=6 b=64 i8 writer (f32)",
        Some(n),
        || {
            enc6.encode_into(&x, 1024, 1024, fmt6, q6, 0).unwrap();
            std::hint::black_box(enc6.storage_bits());
        },
    );
    let wmat = Mat::new(1024, 256, x[..1024 * 256].to_vec()).unwrap();
    suite.bench_items(
        "encode_transposed 1024x256 m=4 b=64 nibble-direct (f32)",
        Some((1024 * 256) as f64),
        || {
            std::hint::black_box(BfpMatrix::encode_transposed(&wmat, fmt, q4).unwrap());
        },
    );
    suite.bench_items(
        "encode_transposed 1024x256 m=6 b=64 i8 writer (f32)",
        Some((1024 * 256) as f64),
        || {
            std::hint::black_box(BfpMatrix::encode_transposed(&wmat, fmt6, q6).unwrap());
        },
    );

    let a = randn(1 << 16, 2);
    let b = randn(1 << 16, 3);
    suite.bench_items("bfp_dot_fixed_point m=4 b=64 (64k)", Some(a.len() as f64), || {
        std::hint::black_box(bfp_dot_fixed_point(&a, &b, fmt).unwrap());
    });

    // --- the acceptance-gate GEMM: 512 x 512 x 512 HBFP4, b = 64 -------
    let dim = 512usize;
    let macs = (dim * dim * dim) as f64;
    let xm = Mat::new(dim, dim, randn(dim * dim, 4)).unwrap();
    let wm = Mat::new(dim, dim, randn(dim * dim, 5)).unwrap();
    suite.bench_items("hbfp_gemm SCALAR 512^3 m=4 b=64 (MACs)", Some(macs), || {
        std::hint::black_box(hbfp_gemm_scalar(&xm, &wm, fmt).unwrap());
    });
    suite.bench_items("hbfp_gemm PACKED 512^3 m=4 b=64 (MACs)", Some(macs), || {
        std::hint::black_box(hbfp_gemm(&xm, &wm, fmt).unwrap());
    });
    // Encode once, GEMM many times — the serving-shaped reuse pattern the
    // packed layout exists for.
    let xp = BfpMatrix::encode(&xm.data, dim, dim, fmt, q4).unwrap();
    let wp = BfpMatrix::encode_transposed(&wm, fmt, q4).unwrap();
    suite.bench_items(
        "BfpMatrix::gemm PACKED pre-encoded 512^3 (MACs)",
        Some(macs),
        || {
            std::hint::black_box(xp.gemm(&wp).unwrap());
        },
    );

    // --- kernel-backend comparison -------------------------------------
    // The same pre-encoded 512^3 operands through every backend the
    // registry registered on this host (auto band count): the per-
    // kernel GEMM throughput series the uploaded BENCH_gemm.json
    // reports. m=4 runs on nibble-packed planes, m=6 on i8 planes, so
    // both nibble-direct and byte inner loops are covered.
    for kernel in registry().all() {
        suite.bench_items(
            &format!("gemm 512^3 m=4 i4x2 kernel={} (MACs)", kernel.name()),
            Some(macs),
            || {
                std::hint::black_box(gemm_packed_with(&xp, &wp, *kernel, None).unwrap());
            },
        );
    }
    let fmt6 = BlockFormat::new(6, 64).unwrap();
    let q6 = Quantizer::nearest(6);
    let xp6 = BfpMatrix::encode(&xm.data, dim, dim, fmt6, q6).unwrap();
    let wp6 = BfpMatrix::encode_transposed(&wm, fmt6, q6).unwrap();
    for kernel in registry().all() {
        suite.bench_items(
            &format!("gemm 512^3 m=6 i8 kernel={} (MACs)", kernel.name()),
            Some(macs),
            || {
                std::hint::black_box(gemm_packed_with(&xp6, &wp6, *kernel, None).unwrap());
            },
        );
    }

    // --- batched serving path: 64 heterogeneous ops ---------------------
    // A weight working set of 8 matrices reused across 64 requests with
    // fresh activations — the serve-sim shape. BatchGemm shards every op
    // into band tasks on the persistent pool and pulls weights from the
    // operand cache; the sequential comparator runs the same ops one
    // hbfp_gemm call at a time (the acceptance-gate comparison).
    let rt = boosters::exec::global();
    let batch_fmt = BlockFormat::new(4, 64).unwrap();
    let wshapes = [(192usize, 96usize), (256, 64), (128, 128), (320, 48)];
    let bweights: Vec<Arc<Mat>> = (0..8)
        .map(|i| {
            let (k, n) = wshapes[i % wshapes.len()];
            Arc::new(Mat::new(k, n, randn(k * n, 100 + i as u64)).unwrap())
        })
        .collect();
    let bxs: Vec<(usize, Arc<Mat>)> = (0..64)
        .map(|i| {
            let wi = i % bweights.len();
            let k = bweights[wi].rows;
            let m = 8 + (i * 7) % 48;
            (wi, Arc::new(Mat::new(m, k, randn(m * k, 200 + i as u64)).unwrap()))
        })
        .collect();
    let batch_macs: f64 = bxs
        .iter()
        .map(|(wi, x)| (x.rows * bweights[*wi].cols * x.cols) as f64)
        .sum();
    suite.bench_items("BatchGemm 64 heterogeneous ops (MACs)", Some(batch_macs), || {
        let ops: Vec<OwnedGemmOp> = bxs
            .iter()
            .map(|(wi, x)| {
                OwnedGemmOp::new(Arc::clone(x), Arc::clone(&bweights[*wi]), batch_fmt).unwrap()
            })
            .collect();
        std::hint::black_box(BatchGemm::new(rt).run(&ops).unwrap());
    });
    // Clone-free one-op-at-a-time baseline: per-op BatchGemm on shared
    // Arcs — the pure execution-stage cost, no service hop, no operand
    // copies. This is the undistorted comparator for the batched bench.
    suite.bench_items(
        "sequential BatchGemm 1-op batches, same 64 ops (MACs)",
        Some(batch_macs),
        || {
            for (wi, x) in &bxs {
                let op =
                    OwnedGemmOp::new(Arc::clone(x), Arc::clone(&bweights[*wi]), batch_fmt).unwrap();
                std::hint::black_box(BatchGemm::new(rt).run(std::slice::from_ref(&op)).unwrap());
            }
        },
    );
    // --- weight-stationary grouping: 64 ops, one shared weight ---------
    // The grouping showcase shape: every op multiplies against the SAME
    // encoded weight, so the grouped run stacks all 64 into one tall-M
    // GEMM per batch and streams the weight planes through memory once
    // per band tile; the ungrouped run re-streams them per op. Same
    // ops, same kernel dispatch, bit-identical outputs — the series
    // pair measures the memory-traffic win (perf_gate checks grouped
    // is never slower).
    let gw = Arc::new(Mat::new(256, 64, randn(256 * 64, 300)).unwrap());
    let gxs: Vec<Arc<Mat>> = (0..64)
        .map(|i| {
            let m = 8 + (i * 7) % 48;
            Arc::new(Mat::new(m, 256, randn(m * 256, 400 + i as u64)).unwrap())
        })
        .collect();
    let group_macs: f64 = gxs.iter().map(|x| (x.rows * 64 * 256) as f64).sum();
    let gops = |xs: &[Arc<Mat>]| -> Vec<OwnedGemmOp> {
        xs.iter()
            .map(|x| OwnedGemmOp::new(Arc::clone(x), Arc::clone(&gw), batch_fmt).unwrap())
            .collect()
    };
    suite.bench_items(
        "BatchGemm 64 shared-weight ops grouped (MACs)",
        Some(group_macs),
        || {
            let ops = gops(&gxs);
            std::hint::black_box(BatchGemm::new(rt).group_min_ops(2).run(&ops).unwrap());
        },
    );
    suite.bench_items(
        "BatchGemm 64 shared-weight ops ungrouped (MACs)",
        Some(group_macs),
        || {
            let ops = gops(&gxs);
            std::hint::black_box(BatchGemm::new(rt).group_min_ops(0).run(&ops).unwrap());
        },
    );
    // The public single-op API: since PR 3 this routes through the
    // async service (admission + ticket + operand copies), so the gap
    // between this series and the 1-op-batch baseline above *is* the
    // per-call service overhead.
    suite.bench_items(
        "sequential hbfp_gemm via service, same 64 ops (MACs)",
        Some(batch_macs),
        || {
            for (wi, x) in &bxs {
                std::hint::black_box(hbfp_gemm(x, &bweights[*wi], batch_fmt).unwrap());
            }
        },
    );
    // Three-stage pipeline: submit all 64 ops up front, then drain the
    // tickets. The decode stage of batch N runs while batch N+1 encodes
    // and executes, and every output/accumulator buffer cycles through
    // the arena — this series is the decode-overlap bench of record.
    let svc = boosters::exec::global_service();
    suite.bench_items(
        "BfpService async pipeline 64 ops decode-overlap (MACs)",
        Some(batch_macs),
        || {
            let tickets: Vec<_> = bxs
                .iter()
                .map(|(wi, x)| {
                    let op = OwnedGemmOp::new(Arc::clone(x), Arc::clone(&bweights[*wi]), batch_fmt)
                        .unwrap();
                    svc.submit_blocking(GemmRequest::new(op)).unwrap()
                })
                .collect();
            for t in &tickets {
                std::hint::black_box(t.wait().unwrap());
            }
        },
    );
    let ss = svc.stats();
    println!(
        "### service pipeline after decode-overlap bench: decode_ops={} overlapped={} ({:.0}%) arena hit rate {:.0}% recycled {} KiB",
        ss.decode_ops,
        ss.decoded_overlapped,
        100.0 * ss.decode_overlap_rate(),
        100.0 * ss.arena_hit_rate(),
        ss.arena_recycled_bytes / 1024
    );
    println!("### exec cache after batch benches: {}", rt.cache_stats().summary());

    suite.finish();
}
