//! Hot-path microbenches for the rust BFP substrate: the quantizer (the
//! L3 analogue of the L1 Pallas kernel), packing, fixed-point dots, and
//! the packed-vs-scalar GEMM comparison that gates the tensor-engine
//! refactor (>= 4x on a 512^3 HBFP4 GEMM). This is the §Perf L3 surface
//! — before/after numbers live in EXPERIMENTS.md.

use boosters::bfp::{
    bfp_dot_fixed_point, hbfp_gemm, hbfp_gemm_scalar, quantize_flat, quantize_packed_into,
    BfpMatrix, BfpTensor, BlockFormat, Mat, Quantizer,
};
use boosters::util::bench::BenchSuite;
use boosters::util::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal_scaled(1.0)).collect()
}

fn main() {
    let mut suite = BenchSuite::new("bfp quantizer + packed tensor engine hot path");
    let x = randn(1 << 20, 1); // 1M elements ≈ a large conv layer
    let n = x.len() as f64;

    for (m, b) in [(4u32, 64usize), (6, 64), (4, 576), (8, 16)] {
        let q = Quantizer::nearest(m);
        suite.bench_items(&format!("quantize_flat m={m} b={b} (1M f32)"), Some(n), || {
            std::hint::black_box(quantize_flat(&x, b, q, 0));
        });
    }
    let qs = Quantizer::stochastic(4, 7);
    suite.bench_items("quantize_flat m=4 b=64 stochastic (1M f32)", Some(n), || {
        std::hint::black_box(quantize_flat(&x, 64, qs, 0));
    });

    // Packed carrier: encode/decode into reused planes (zero steady-state
    // allocation) vs the per-block BfpTensor objects.
    let fmt = BlockFormat::new(4, 64).unwrap();
    let q4 = Quantizer::nearest(4);
    suite.bench_items("BfpTensor::encode m=4 b=64 (1M f32)", Some(n), || {
        std::hint::black_box(BfpTensor::encode(&x, fmt).unwrap());
    });
    let enc = BfpTensor::encode(&x, fmt).unwrap();
    suite.bench_items("BfpTensor::decode m=4 b=64 (1M f32)", Some(n), || {
        std::hint::black_box(enc.decode());
    });
    let mut packed = BfpMatrix::empty();
    suite.bench_items("BfpMatrix::encode_into m=4 b=64 (1M f32)", Some(n), || {
        packed.encode_into(&x, 1, x.len(), fmt, q4, 0).unwrap();
        std::hint::black_box(packed.storage_bits());
    });
    let mut dec = Vec::new();
    suite.bench_items("BfpMatrix::decode_into m=4 b=64 (1M f32)", Some(n), || {
        packed.decode_into(&mut dec);
        std::hint::black_box(dec.len());
    });
    let mut qout = Vec::new();
    suite.bench_items(
        "quantize_packed_into m=4 b=64 reused bufs (1M f32)",
        Some(n),
        || {
            quantize_packed_into(&x, 64, q4, 0, &mut packed, &mut qout).unwrap();
            std::hint::black_box(qout.len());
        },
    );

    let a = randn(1 << 16, 2);
    let b = randn(1 << 16, 3);
    suite.bench_items("bfp_dot_fixed_point m=4 b=64 (64k)", Some(a.len() as f64), || {
        std::hint::black_box(bfp_dot_fixed_point(&a, &b, fmt).unwrap());
    });

    // --- the acceptance-gate GEMM: 512 x 512 x 512 HBFP4, b = 64 -------
    let dim = 512usize;
    let macs = (dim * dim * dim) as f64;
    let xm = Mat::new(dim, dim, randn(dim * dim, 4)).unwrap();
    let wm = Mat::new(dim, dim, randn(dim * dim, 5)).unwrap();
    suite.bench_items("hbfp_gemm SCALAR 512^3 m=4 b=64 (MACs)", Some(macs), || {
        std::hint::black_box(hbfp_gemm_scalar(&xm, &wm, fmt).unwrap());
    });
    suite.bench_items("hbfp_gemm PACKED 512^3 m=4 b=64 (MACs)", Some(macs), || {
        std::hint::black_box(hbfp_gemm(&xm, &wm, fmt).unwrap());
    });
    // Encode once, GEMM many times — the serving-shaped reuse pattern the
    // packed layout exists for.
    let xp = BfpMatrix::encode(&xm.data, dim, dim, fmt, q4).unwrap();
    let wp = BfpMatrix::encode_transposed(&wm, fmt, q4).unwrap();
    suite.bench_items(
        "BfpMatrix::gemm PACKED pre-encoded 512^3 (MACs)",
        Some(macs),
        || {
            std::hint::black_box(xp.gemm(&wp).unwrap());
        },
    );

    suite.finish();
}
