//! Hot-path microbenches for the rust BFP substrate: the quantizer (the
//! L3 analogue of the L1 Pallas kernel), packing, and fixed-point dots.
//! This is the §Perf L3 surface — before/after numbers live in
//! EXPERIMENTS.md.

use boosters::bfp::{
    bfp_dot_fixed_point, quantize_flat, BfpTensor, BlockFormat, Quantizer,
};
use boosters::util::bench::BenchSuite;
use boosters::util::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal_scaled(1.0)).collect()
}

fn main() {
    let mut suite = BenchSuite::new("bfp quantizer hot path");
    let x = randn(1 << 20, 1); // 1M elements ≈ a large conv layer
    let n = x.len() as f64;

    for (m, b) in [(4u32, 64usize), (6, 64), (4, 576), (8, 16)] {
        let q = Quantizer::nearest(m);
        suite.bench_items(&format!("quantize_flat m={m} b={b} (1M f32)"), Some(n), || {
            std::hint::black_box(quantize_flat(&x, b, q, 0));
        });
    }
    let qs = Quantizer::stochastic(4, 7);
    suite.bench_items("quantize_flat m=4 b=64 stochastic (1M f32)", Some(n), || {
        std::hint::black_box(quantize_flat(&x, 64, qs, 0));
    });

    let fmt = BlockFormat::new(4, 64).unwrap();
    suite.bench_items("BfpTensor::encode m=4 b=64 (1M f32)", Some(n), || {
        std::hint::black_box(BfpTensor::encode(&x, fmt).unwrap());
    });
    let enc = BfpTensor::encode(&x, fmt).unwrap();
    suite.bench_items("BfpTensor::decode m=4 b=64 (1M f32)", Some(n), || {
        std::hint::black_box(enc.decode());
    });

    let a = randn(1 << 16, 2);
    let b = randn(1 << 16, 3);
    suite.bench_items("bfp_dot_fixed_point m=4 b=64 (64k)", Some(a.len() as f64), || {
        std::hint::black_box(bfp_dot_fixed_point(&a, &b, fmt).unwrap());
    });

    suite.finish();
}
