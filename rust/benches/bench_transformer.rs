//! Table-3 bench: transformer train-step and greedy-decode latency (the
//! two phases behind the BLEU table), per precision.

use boosters::config::PrecisionPolicy;
use boosters::coordinator::{init_state, TrainerData};
use boosters::experiments::common::config_for;
use boosters::experiments::Preset;
use boosters::runtime::{artifacts_dir, Engine, StepScalars};
use boosters::util::bench::BenchSuite;

fn main() {
    let artifacts = artifacts_dir();
    if !artifacts.join("index.json").exists() {
        println!("### bench skipped: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let engine = Engine::new().expect("pjrt client");
    let v = engine
        .load_variant_by_name(&artifacts, "transformer_bs64")
        .expect("transformer_bs64");
    let cfg = config_for(&v, PrecisionPolicy::booster(1), Preset::Quick);
    let data = TrainerData::for_variant(&v, &cfg).expect("data");
    let text = match &data {
        TrainerData::Text(t) => t,
        _ => unreachable!(),
    };
    let mut state = init_state(&v.manifest, 1).expect("init");
    let idx: Vec<usize> = (0..v.manifest.batch).collect();
    let (x, y) = data.batch(&idx, false);
    let (src, _refs) = text.decode_batch(&idx, true);

    let mut suite = BenchSuite::new("transformer: step + decode latency");
    for (label, sc) in [
        ("fp32", StepScalars::fp32()),
        ("hbfp6", StepScalars::hbfp(6.0)),
        ("hbfp4", StepScalars::hbfp(4.0)),
    ] {
        suite.bench_items(
            &format!("train_step {label} (batch {})", v.manifest.batch),
            Some(v.manifest.batch as f64),
            || {
                std::hint::black_box(
                    engine.train_step(&v, &mut state, &x, &y, sc, 1e-4).unwrap(),
                );
            },
        );
        suite.bench_items(
            &format!("greedy_decode {label} (batch {})", v.manifest.batch),
            Some(v.manifest.batch as f64),
            || {
                std::hint::black_box(engine.decode(&v, &state, &src, sc).unwrap());
            },
        );
    }
    suite.finish();
}
