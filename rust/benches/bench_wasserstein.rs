//! Fig-1 bench: Wasserstein-distance computation over quantized tensors —
//! the analysis path that sweeps (layer x format x block) on checkpoints.

use boosters::metrics::{wasserstein1, wasserstein1_quantized};
use boosters::util::bench::BenchSuite;
use boosters::util::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal_scaled(0.1)).collect()
}

fn main() {
    let mut suite = BenchSuite::new("wasserstein: Fig-1 analysis path");
    // Typical CNN layer sizes in this repro.
    for n in [432usize, 9216, 147_456] {
        let x = randn(n, n as u64);
        suite.bench_items(&format!("W1(x, Q_4,64(x)) n={n}"), Some(n as f64), || {
            std::hint::black_box(wasserstein1_quantized(&x, 4, 64));
        });
    }
    let a = randn(65_536, 1);
    let b = randn(65_536, 2);
    suite.bench_items("W1 equal-size 64k", Some(65_536.0), || {
        std::hint::black_box(wasserstein1(&a, &b));
    });
    suite.bench_items("W1 unequal-size 64k vs 16k (quantile grid)", None, || {
        std::hint::black_box(wasserstein1(&a, &b[..16_384]));
    });
    // The full Fig-1 sweep shape: 4 layers x 2 formats x 7 blocks.
    let layers: Vec<Vec<f32>> = vec![randn(432, 3), randn(2304, 4), randn(9216, 5), randn(320, 6)];
    suite.bench("fig1 full sweep (4 layers x 2 fmts x 7 blocks)", || {
        for l in &layers {
            for m in [6u32, 4] {
                for b in [16usize, 25, 36, 49, 64, 256, 576] {
                    std::hint::black_box(wasserstein1_quantized(l, m, b));
                }
            }
        }
    });
    suite.finish();
}
