//! Fig 6 + §4.2 density regeneration bench: evaluates the full analytic
//! area model across the paper's block-size sweep and prints the series
//! (values are checked in unit tests; here we time the evaluation and
//! emit the numbers that go into EXPERIMENTS.md).

use boosters::hw_model::{area_gain_hbfp, bf16_gain, fig6_series};
use boosters::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("analytic area model (Fig 6 / Table 1 gains)");
    let blocks: Vec<u64> = vec![4, 8, 16, 25, 36, 49, 64, 128, 256, 400, 576, 1024];

    suite.bench("fig6_series full sweep", || {
        std::hint::black_box(fig6_series(&blocks));
    });

    println!("\nblock  HBFP8  HBFP6  HBFP5  HBFP4");
    for row in fig6_series(&blocks) {
        println!(
            "{:5}  {:5.2}  {:5.2}  {:5.2}  {:5.2}",
            row.block, row.hbfp8, row.hbfp6, row.hbfp5, row.hbfp4
        );
    }
    println!(
        "\nheadline: HBFP4@64 {:.1}x vs FP32 (paper 21.3x), BF16 {:.1}x (paper 4.9x)",
        area_gain_hbfp(4, 64),
        bf16_gain(64)
    );
    suite.finish();
}
