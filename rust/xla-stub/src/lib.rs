//! API-compatible stand-in for the `xla-rs` bindings (xla_extension
//! 0.5.x) used by `boosters::runtime`.
//!
//! The host-literal surface ([`Literal`], [`ArrayShape`]) is fully
//! functional — tensors round-trip through it losslessly, so every
//! host-side code path (BFP substrate, analysis, checkpointing,
//! coordinator state plumbing) works as in the real build. What a stub
//! cannot do is compile and execute HLO: [`PjRtClient::compile`]
//! returns an error, so artifact-backed paths (`Engine::load_variant`)
//! fail cleanly at run time with an actionable message. Swapping this
//! crate for the real `xla` dependency requires no source changes in
//! `boosters`.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: every failure is a message string.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: &str) -> Result<T> {
    Err(Error(msg.to_string()))
}

/// Element types we model (the system only uses F32 and S32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    Tuple,
}

/// Array payload of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host literal: an n-d array (f32 or i32) or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    Array { dims: Vec<i64>, data: Storage },
    Tuple(Vec<Literal>),
}

/// Shape view returned by [`Literal::array_shape`].
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ptype: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ptype
    }
}

/// Native element types convertible to/from [`Literal`] arrays.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Storage;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Storage {
        Storage::F32(data)
    }

    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::Array {
                data: Storage::F32(d),
                ..
            } => Ok(d.clone()),
            _ => err("literal is not an f32 array"),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Storage {
        Storage::I32(data)
    }

    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::Array {
                data: Storage::I32(d),
                ..
            } => Ok(d.clone()),
            _ => err("literal is not an i32 array"),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal::Array {
            dims: vec![],
            data: Storage::F32(vec![v]),
        }
    }

    fn numel(&self) -> usize {
        match self {
            Literal::Array { data, .. } => match data {
                Storage::F32(d) => d.len(),
                Storage::I32(d) => d.len(),
            },
            Literal::Tuple(_) => 0,
        }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.numel() {
            return err(&format!(
                "reshape to {dims:?} ({n} elems) from {} elems",
                self.numel()
            ));
        }
        match self {
            Literal::Array { data, .. } => Ok(Literal::Array {
                dims: dims.to_vec(),
                data: data.clone(),
            }),
            Literal::Tuple(_) => err("cannot reshape a tuple literal"),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, data } => Ok(ArrayShape {
                dims: dims.clone(),
                ptype: match data {
                    Storage::F32(_) => PrimitiveType::F32,
                    Storage::I32(_) => PrimitiveType::S32,
                },
            }),
            Literal::Tuple(_) => err("tuple literal has no array shape"),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(v) => Ok(v),
            Literal::Array { .. } => err("literal is not a tuple"),
        }
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        let mut v = self.to_tuple()?;
        if v.len() != 1 {
            return err(&format!("expected 1-tuple, got {}-tuple", v.len()));
        }
        Ok(v.pop().unwrap())
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        let mut v = self.to_tuple()?;
        if v.len() != 2 {
            return err(&format!("expected 2-tuple, got {}-tuple", v.len()));
        }
        let b = v.pop().unwrap();
        let a = v.pop().unwrap();
        Ok((a, b))
    }
}

/// Parsed HLO module handle. The stub verifies the file is readable but
/// does not parse HLO text.
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::metadata(path) {
            Ok(_) => Ok(HloModuleProto {
                path: path.to_string(),
            }),
            Err(e) => err(&format!("reading HLO text {path}: {e}")),
        }
    }
}

/// Computation handle built from a parsed module.
pub struct XlaComputation {
    path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            path: proto.path.clone(),
        }
    }
}

/// Device buffer handle returned by execution (never constructed here:
/// the stub cannot execute).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err("xla stub: no device buffers exist in this build")
    }
}

/// Loaded executable handle (never constructed: compilation fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err("xla stub: execution requires the xla_extension native library")
    }
}

/// CPU PJRT client. Construction succeeds (host-side tooling keeps
/// working); compilation reports the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (xla_extension unavailable; compiled artifacts disabled)".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(&format!(
            "xla stub: cannot compile {} — link the real xla crate (xla_extension 0.5.x) to run artifacts",
            comp.path
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert!(matches!(s.primitive_type(), PrimitiveType::F32));
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn tuples_and_scalars() {
        let t = Literal::Tuple(vec![Literal::scalar(1.0), Literal::scalar(2.0)]);
        let (a, b) = t.to_tuple2().unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), vec![1.0]);
        assert_eq!(b.to_vec::<f32>().unwrap(), vec![2.0]);
        assert!(Literal::scalar(0.0).to_tuple().is_err());
    }

    #[test]
    fn client_compiles_nothing() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }
}
