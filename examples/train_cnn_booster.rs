//! End-to-end validation driver (DESIGN.md requirement): trains the CNN
//! on the synthetic CIFAR stand-in under FP32, standalone HBFP4, and the
//! Accuracy Booster, logging full loss curves — the run recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example train_cnn_booster [-- full]`

use anyhow::Result;
use boosters::config::PrecisionPolicy;
use boosters::coordinator::TrainerData;
use boosters::experiments::common::{config_for, run_one};
use boosters::experiments::Preset;
use boosters::report::{results_dir, Table};
use boosters::runtime::{artifacts_dir, Engine};

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "full");
    let preset = if full { Preset::Full } else { Preset::Quick };
    let engine = Engine::new()?;
    let variant = engine.load_variant_by_name(&artifacts_dir(), "cnn_bs64")?;
    let cfg0 = config_for(&variant, PrecisionPolicy::Fp32, preset);
    let data = TrainerData::for_variant(&variant, &cfg0)?;
    println!(
        "CNN: {} params, block 64, {} epochs x {} steps, batch {}",
        variant.manifest.total_weights(),
        cfg0.epochs,
        cfg0.steps_per_epoch,
        variant.manifest.batch
    );

    let mut table = Table::new(
        "End-to-end: CNN on synthetic CIFAR stand-in",
        &["policy", "final_val_acc", "best_val_acc", "wall_secs"],
    );
    for policy in [
        PrecisionPolicy::Fp32,
        PrecisionPolicy::Hbfp { bits: 4 },
        PrecisionPolicy::booster(1),
    ] {
        let cfg = config_for(&variant, policy.clone(), preset);
        println!("--- {}", policy.label());
        let (acc, hist, _) = run_one(&engine, &variant, &data, cfg, true)?;
        hist.write_csv(
            &results_dir().join(format!(
                "e2e_cnn_{}.csv",
                policy.label().replace(['+', '(', ')'], "_")
            )),
        )?;
        table.row(vec![
            policy.label(),
            format!("{acc:.4}"),
            format!("{:.4}", hist.best_val_acc()),
            format!("{:.1}", hist.total_wall_secs()),
        ]);
    }
    table.print();
    table.write_csv(&results_dir().join("e2e_cnn_summary.csv"))?;
    println!("curves in results/e2e_cnn_*.csv");
    Ok(())
}
