//! Table-3 scenario as a standalone example: train the decoder-only
//! transformer on the synthetic translation task under each precision
//! policy, greedy-decode the validation set, and score corpus BLEU.
//!
//! Run: `cargo run --release --example transformer_bleu [-- full]`

use anyhow::Result;
use boosters::experiments::{table3, Preset};
use boosters::runtime::{artifacts_dir, Engine};

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "full");
    let preset = if full { Preset::Full } else { Preset::Quick };
    let engine = Engine::new()?;
    let table = table3::run(&engine, &artifacts_dir(), preset)?;
    table.print();
    println!("(paper Table 3: FP32 34.77, HBFP6 34.47, HBFP4 32.64, Booster 36.08)");
    Ok(())
}
