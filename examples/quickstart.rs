//! Quickstart — the smallest end-to-end use of the public API.
//!
//! Loads the *Pallas-kernel* MLP artifact (the quantizer inside this HLO
//! was authored as a Pallas kernel, proving the L1->L2->L3 composition),
//! trains it for a few epochs under the Accuracy Booster schedule, and
//! prints the loss curve.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use boosters::config::PrecisionPolicy;
use boosters::coordinator::{Trainer, TrainerData};
use boosters::experiments::common::config_for;
use boosters::experiments::Preset;
use boosters::runtime::{artifacts_dir, Engine};

fn main() -> Result<()> {
    let artifacts = artifacts_dir();
    let engine = Engine::new()?;
    println!("PJRT platform: {}", engine.platform());

    // The _pallas variant's quantizer was lowered from the Pallas kernel
    // (interpret mode); numerics are bit-identical to the jnp path.
    let variant = engine.load_variant_by_name(&artifacts, "mlp_bs64_pallas")?;
    println!(
        "loaded {} ({} params, block={}, pallas={})",
        variant.manifest.variant,
        variant.manifest.total_weights(),
        variant.manifest.block,
        variant.manifest.pallas,
    );

    let mut cfg = config_for(&variant, PrecisionPolicy::booster(1), Preset::Quick);
    cfg.epochs = 6;
    let data = TrainerData::for_variant(&variant, &cfg)?;

    let result = Trainer::new(&engine, &variant, &data, cfg)
        .with_progress(|e| {
            println!(
                "epoch {:>2}  train_loss {:.4}  val_acc {:.4}  mantissa bits {}/{}",
                e.epoch, e.train_loss, e.val_acc, e.bits_mid, e.bits_edge
            );
        })
        .run()?;

    println!(
        "final val acc {:.4} — note the last epoch runs at 6-bit mantissas \
         (the Booster) while all earlier epochs ran at 4.",
        result.final_val_acc()
    );
    Ok(())
}
