//! Fig-1 scenario as a standalone example: quantize the weights of a
//! trained FP32 CNN at HBFP6/HBFP4 across block sizes and report the
//! Wasserstein distances per layer, plus the §3 R² association between
//! distance and accuracy (computed over the stored Table-1 CSV when one
//! exists from a previous `repro table1` run).
//!
//! Run: `cargo run --release --example wasserstein_report`

use anyhow::Result;
use boosters::experiments::{figs, Preset};
use boosters::metrics::r_squared;
use boosters::report::results_dir;
use boosters::runtime::{artifacts_dir, Engine};

fn main() -> Result<()> {
    let engine = Engine::new()?;
    let table = figs::fig1(&engine, &artifacts_dir(), Preset::Quick)?;
    table.print();

    // Optional R² cross-check against an existing Table-1 sweep: join the
    // mean Wasserstein distance per (format, block) with its accuracy.
    let t1 = results_dir().join("table1_cnn.csv");
    let w1 = results_dir().join("fig1_wasserstein.csv");
    if t1.exists() && w1.exists() {
        let parse = |p: &std::path::Path| -> Vec<Vec<String>> {
            std::fs::read_to_string(p)
                .unwrap_or_default()
                .lines()
                .skip(1)
                .map(|l| l.split(',').map(str::to_string).collect())
                .collect()
        };
        let acc_rows = parse(&t1);
        let w_rows = parse(&w1);
        let mut dists = Vec::new();
        let mut accs = Vec::new();
        for row in &acc_rows {
            // ["HBFP4", "64", gain, bits_per_val, plane, acc, best]
            let (fmt, block) = (&row[0], &row[1]);
            if fmt == "FP32" || row.len() < 6 {
                continue;
            }
            let ws: Vec<f64> = w_rows
                .iter()
                .filter(|w| &w[1] == fmt && &w[2] == block)
                .filter_map(|w| w[3].parse().ok())
                .collect();
            if ws.is_empty() {
                continue;
            }
            dists.push(ws.iter().sum::<f64>() / ws.len() as f64);
            accs.push(row[5].parse::<f64>().unwrap_or(0.0));
        }
        if dists.len() >= 3 {
            println!(
                "\nR²(Wasserstein distance, val accuracy) over {} sweep points: {:.3}",
                dists.len(),
                r_squared(&dists, &accs)
            );
            println!("(paper §3 reports ≈0.99 on its sweep)");
        }
    } else {
        println!("\n(run `repro table1 --model cnn` first to get the R² join)");
    }
    Ok(())
}
