//! Explore the gate-level silicon-area model: Fig 6 sweep, the §4.2
//! density headline, per-component area breakdowns, and the
//! bits-per-value storage table — all pure analytic (no artifacts).
//!
//! Run: `cargo run --release --example area_explorer`

use anyhow::Result;
use boosters::bfp::bits_per_value;
use boosters::experiments::figs;
use boosters::hw_model::{bf16_dot_unit, fp32_dot_unit, hbfp_dot_unit};
use boosters::report::Table;

fn main() -> Result<()> {
    figs::fig6()?.print();
    println!();
    figs::density()?.print();
    println!();

    let mut breakdown = Table::new(
        "Dot-unit area breakdown @ N = 64 (gate counts)",
        &["unit", "multipliers", "adder_tree", "acc+act", "exp", "converters", "total"],
    );
    for (name, u) in [
        ("FP32", fp32_dot_unit(64)),
        ("BF16", bf16_dot_unit(64)),
        ("HBFP8", hbfp_dot_unit(8, 64)),
        ("HBFP6", hbfp_dot_unit(6, 64)),
        ("HBFP4", hbfp_dot_unit(4, 64)),
    ] {
        breakdown.row(vec![
            name.into(),
            u.multipliers.to_string(),
            u.adder_tree.to_string(),
            (u.accumulator + u.activation).to_string(),
            u.exponent_logic.to_string(),
            u.converters.to_string(),
            u.total().to_string(),
        ]);
    }
    breakdown.print();
    println!();

    let mut storage = Table::new(
        "Storage: bits/value (mantissa + amortized 10-bit exponent)",
        &["format", "b=16", "b=64", "b=576", "vs FP32 @64"],
    );
    for m in [8u32, 6, 5, 4] {
        storage.row(vec![
            format!("HBFP{m}"),
            format!("{:.2}", bits_per_value(m, 16)),
            format!("{:.2}", bits_per_value(m, 64)),
            format!("{:.2}", bits_per_value(m, 576)),
            format!("{:.1}x", 32.0 / bits_per_value(m, 64)),
        ]);
    }
    storage.print();
    Ok(())
}
