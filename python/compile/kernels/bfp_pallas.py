"""L1 — Pallas kernels for the HBFP hot spot.

Two kernels:

  * ``bfp_quantize_pallas`` — the BFP quantizer over a (nblocks, block)
    array, tiled so each grid step owns ``tile_nb`` blocks. Numerically
    identical (bit-exact) to ``ref.quantize_blocks``; the model path can be
    built on top of it (``aot.py --pallas``) and is asserted against the
    jnp path in pytest.

  * ``bfp_matmul_pallas`` — a fused quantize+matmul: the MXU-oriented
    adaptation of the paper's fixed-point datapath. Operand tiles are
    quantized in VMEM (one shared exponent per ``bk``-wide row — the HBFP
    block) immediately before the dot, the way an HBFP accelerator converts
    on the fly ahead of its systolic array. Used by the kernel benchmarks
    and validated against ``ref.pallas_tile_quantize_ref`` composition.

TPU mapping (DESIGN.md §Hardware-Adaptation): BlockSpecs below are chosen
so an operand tile + its quantized copy stay < 4 MiB VMEM and the dot hits
the 128x128 MXU shape. On this image Pallas must run ``interpret=True``
(the CPU PJRT plugin cannot execute Mosaic custom-calls), so these kernels
are *numerics-exact, structure-only* stand-ins for the TPU build; VMEM and
MXU utilization are estimated analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Interpret mode is mandatory on CPU-PJRT (see module docstring).
INTERPRET = True

# Default tile sizes. 8 blocks per grid step keeps the quantizer tile
# (8 x 576 x 4 B x 2 copies ~= 36 KiB) far under VMEM even for the largest
# paper block size; the matmul tiles target the 128-lane MXU geometry.
TILE_NB = 8
TILE_M = 32
TILE_N = 32


def _quantize_tile(v, m_bits, rmode, seed, base_idx):
    """Quantize a (tnb, b) tile; same algebra as ref.quantize_blocks."""
    tnb, b = v.shape
    maxabs = jnp.max(jnp.abs(v), axis=1, keepdims=True)
    bits = lax.bitcast_convert_type(maxabs, jnp.uint32)
    e = (((bits >> jnp.uint32(23)) & jnp.uint32(0xFF)).astype(jnp.int32) - 127).astype(
        jnp.float32
    )
    s = jnp.exp2(e - m_bits + 2.0)
    half = jnp.exp2(m_bits - 1.0)
    idx = base_idx + lax.broadcasted_iota(jnp.uint32, (tnb, b), 0) * jnp.uint32(
        b
    ) + lax.broadcasted_iota(jnp.uint32, (tnb, b), 1)
    scaled = v / s
    h = (idx * jnp.uint32(2654435761) + seed * jnp.uint32(0x9E3779B9)).astype(jnp.uint32)
    h = h ^ (h << jnp.uint32(13))
    h = h ^ (h >> jnp.uint32(17))
    h = h ^ (h << jnp.uint32(5))
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    q = jnp.where(rmode > 0.5, jnp.floor(scaled + u), jnp.round(scaled))
    q = jnp.clip(q, -half, half - 1.0)
    out = q * s
    out = jnp.where(maxabs < jnp.float32(2.0**-126), 0.0, out)
    return jnp.where(m_bits >= 23.0, v, out)


def _quant_kernel(scal_ref, v_ref, o_ref, *, block: int, tile_nb: int):
    m_bits = scal_ref[0]
    rmode = scal_ref[1]
    seed = scal_ref[2].astype(jnp.uint32)
    base = scal_ref[3].astype(jnp.uint32)
    tile = pl.program_id(0)
    # Global element index of this tile's first element (row-major).
    tile_base = base + (tile * tile_nb * block).astype(jnp.uint32)
    o_ref[...] = _quantize_tile(v_ref[...], m_bits, rmode, seed, tile_base)


def bfp_quantize_pallas(
    v: jax.Array,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    base_idx: jax.Array,
    tile_nb: int = TILE_NB,
) -> jax.Array:
    """Pallas BFP quantizer over (nblocks, block); bit-exact vs ref.

    ``nblocks`` is padded up to a multiple of ``tile_nb`` internally;
    padded rows quantize to zero and are stripped before returning.
    """
    nb, block = v.shape
    tile_nb = min(tile_nb, max(nb, 1))
    pad = (-nb) % tile_nb
    vp = jnp.pad(v, ((0, pad), (0, 0)))
    nbp = nb + pad
    scal = jnp.stack(
        [
            m_bits.astype(jnp.float32),
            rmode.astype(jnp.float32),
            seed.astype(jnp.float32),
            base_idx.astype(jnp.float32),
        ]
    )
    out = pl.pallas_call(
        functools.partial(_quant_kernel, block=block, tile_nb=tile_nb),
        grid=(nbp // tile_nb,),
        in_specs=[
            # Scalars are replicated to every grid step (index_map -> 0).
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((tile_nb, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_nb, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        interpret=INTERPRET,
    )(scal, vp.astype(jnp.float32))
    return out[:nb]


def _matmul_kernel(scal_ref, x_ref, w_ref, o_ref, *, bk: int):
    """One (TM, TN) output tile; k-loop is grid dim 2 with accumulation.

    Operand tiles are quantized with tile-local blocking: one shared
    exponent per bk-wide row of x, and per bk-wide column of w (i.e. the
    contraction dimension is the block dimension on both sides), exactly
    what an HBFP converter in front of a systolic array does.
    """
    m_bits = scal_ref[0]
    rmode = scal_ref[1]
    seed = scal_ref[2].astype(jnp.uint32)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (TM, bk)
    w = w_ref[...]  # (bk, TN)
    zero = jnp.uint32(0)
    xq = _quantize_tile(x, m_bits, rmode, seed, zero)
    wq = _quantize_tile(w.T, m_bits, rmode, seed, zero).T
    o_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def bfp_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    block: int = 64,
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
) -> jax.Array:
    """Fused BFP matmul: y = Q_tile(x) @ Q_tile(w), blocks of ``block``
    along K. Shapes must divide evenly by the tile sizes (bench path)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (k, k2)
    assert m % tile_m == 0 and n % tile_n == 0 and k % block == 0, (m, n, k, block)
    scal = jnp.stack(
        [m_bits.astype(jnp.float32), rmode.astype(jnp.float32), seed.astype(jnp.float32)]
    )
    return pl.pallas_call(
        functools.partial(_matmul_kernel, bk=block),
        grid=(m // tile_m, n // tile_n, k // block),
        in_specs=[
            pl.BlockSpec((3,), lambda i, j, kk: (0,)),
            pl.BlockSpec((tile_m, block), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(scal, x.astype(jnp.float32), w.astype(jnp.float32))


def quantize_flat_pallas(
    t: jax.Array,
    block: int,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    site: int,
) -> jax.Array:
    """Drop-in replacement for ref.quantize_flat built on the Pallas
    quantizer; used when artifacts are built with --pallas."""
    flat = t.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    padded = jnp.pad(flat, (0, pad))
    blocks = padded.reshape(-1, block)
    base = jnp.uint32(site * 40503)  # < 2^24, survives the f32 round-trip
    out = bfp_quantize_pallas(
        blocks, m_bits, rmode, seed.astype(jnp.uint32), base
    )
    return out.reshape(-1)[:n].reshape(t.shape)
