"""Pure-jnp reference (oracle) for BFP quantization and blocked dot products.

This module is the single source of truth for HBFP numerics. The Pallas
kernel (`bfp_pallas.py`) and the Rust software implementation
(`rust/src/bfp/`) must match it **bit-exactly**; golden vectors generated
from this module (see `python/compile/golden.py`) pin the contract.

Quantization scheme (see DESIGN.md §2):

  For a block v[0..b) and mantissa width ``m`` (two's complement, sign
  included):

    e     = floor(log2(max|v|))          -- IEEE exponent field, bit-exact
    s     = 2^(e - m + 2)                -- the Eq.1 interval
    q     = clamp(round(v / s), -2^(m-1), 2^(m-1) - 1)
    v_hat = q * s

  * All-zero / denormal-max blocks dequantize to exactly 0.
  * ``m >= 23`` is the FP32 bypass (identity) by convention: the shared
    exponent plus a >=23-bit mantissa subsumes f32 precision, and the rust
    coordinator uses it to run the FP32 baseline from the same executable.
  * Rounding is round-half-to-even (``rmode == 0``) or stochastic with a
    counter-based XORshift hash (``rmode == 1``).

All functions take mantissa width / rounding mode / seed as *traced scalar
arrays* so that the AOT-compiled step function can be steered by the rust
coordinator at runtime without recompilation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# Exponent of the smallest normal f32; blocks whose max|v| is below this
# (i.e. zero or denormal) quantize to exactly zero.
_MIN_NORMAL_EXP = -126


def floor_log2(x: jax.Array) -> jax.Array:
    """floor(log2(x)) for positive normal f32 via the IEEE exponent field.

    Bit-exact and reproducible across jnp / Pallas / rust (f32::to_bits).
    Returns -127 for zeros and denormals (callers must mask those blocks).
    """
    bits = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return ((bits >> jnp.uint32(23)) & jnp.uint32(0xFF)).astype(jnp.int32) - 127


def xorshift_hash(idx: jax.Array, seed: jax.Array) -> jax.Array:
    """Counter-based XORshift32 hash -> u32. idx/seed are u32 arrays.

    Mirrors the XORshift circuits the paper's area model prices for
    stochastic rounding; identical algebra in rust/src/bfp/rounding.rs.
    """
    h = (idx * jnp.uint32(2654435761) + seed * jnp.uint32(0x9E3779B9)).astype(jnp.uint32)
    h = h ^ (h << jnp.uint32(13))
    h = h ^ (h >> jnp.uint32(17))
    h = h ^ (h << jnp.uint32(5))
    return h


def uniform_u01(idx: jax.Array, seed: jax.Array) -> jax.Array:
    """u in [0, 1) with 24 bits of randomness from xorshift_hash."""
    h = xorshift_hash(idx, seed)
    return (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def _round(x: jax.Array, rmode: jax.Array, idx: jax.Array, seed: jax.Array) -> jax.Array:
    """rmode == 0 -> round-half-to-even; rmode == 1 -> stochastic."""
    nearest = jnp.round(x)  # ties-to-even, matches f32::round_ties_even
    u = uniform_u01(idx, seed)
    stochastic = jnp.floor(x + u)
    return jnp.where(rmode > 0.5, stochastic, nearest)


def quantize_blocks(
    v: jax.Array,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    base_idx: jax.Array,
) -> jax.Array:
    """Quantize ``v`` of shape (nblocks, b): one shared exponent per row.

    ``m_bits``/``rmode``/``seed``/``base_idx`` are scalar arrays (f32/f32/
    u32/u32). Returns dequantized values, same shape/dtype as ``v``.
    """
    v = v.astype(jnp.float32)
    nb, b = v.shape
    maxabs = jnp.max(jnp.abs(v), axis=1, keepdims=True)
    e = floor_log2(maxabs).astype(jnp.float32)
    # s = 2^(e - m + 2); exp2 on integer-valued floats is exact.
    s = jnp.exp2(e - m_bits + 2.0)
    half = jnp.exp2(m_bits - 1.0)  # 2^(m-1)
    idx = base_idx + jnp.arange(nb * b, dtype=jnp.uint32).reshape(nb, b)
    q = _round(v / s, rmode, idx, seed)
    q = jnp.clip(q, -half, half - 1.0)
    out = q * s
    # zero/denormal blocks -> 0; m >= 23 -> FP32 bypass.
    out = jnp.where(maxabs < jnp.float32(2.0**_MIN_NORMAL_EXP), 0.0, out)
    return jnp.where(m_bits >= 23.0, v, out)


def quantize_flat(
    t: jax.Array,
    block: int,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    site: int,
) -> jax.Array:
    """Quantize an arbitrary tensor in row-major blocks of ``block``.

    Callers arrange the contraction axis last so blocks run along it
    (wrapping to the next row when the axis is shorter than the block, as
    in 2-D HBFP tiles). ``site`` is a static per-call-site salt keeping
    stochastic rounding streams independent.
    """
    flat = t.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    padded = jnp.pad(flat, (0, pad))
    blocks = padded.reshape(-1, block)
    # Salt kept < 2^24 per site so it survives an f32 round-trip when the
    # Pallas path ships it through a float scalar vector (bit-exactness).
    base = jnp.uint32(site * 40503)
    out = quantize_blocks(blocks, m_bits, rmode, seed.astype(jnp.uint32), base)
    return out.reshape(-1)[:n].reshape(t.shape)


def quantize_along_axis(
    t: jax.Array,
    axis: int,
    block: int,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    site: int,
) -> jax.Array:
    """Move ``axis`` last, quantize row-major blocks, move back."""
    moved = jnp.moveaxis(t, axis, -1)
    q = quantize_flat(moved, block, m_bits, rmode, seed, site)
    return jnp.moveaxis(q, -1, axis)


def bfp_dot_ref(
    x: jax.Array,
    w: jax.Array,
    block: int,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    site: int = 0,
) -> jax.Array:
    """Reference HBFP forward dot: y = Q(x) @ Q(w), blocks along K.

    x: [M, K], w: [K, N]. Both operands quantized with the contraction
    dimension innermost (w is transposed for blocking, then restored).
    """
    xq = quantize_flat(x, block, m_bits, rmode, seed, site)
    wq = quantize_along_axis(w, 0, block, m_bits, rmode, seed, site + 1)
    return xq @ wq


def pallas_tile_quantize_ref(
    v: jax.Array, m_bits: jax.Array, rmode: jax.Array, seed: jax.Array
) -> jax.Array:
    """Oracle for the fused Pallas matmul's *tile-local* blocking.

    The fused kernel (bench-only path) quantizes each (tm, bk) operand tile
    with one exponent per row of the tile; for a (nb, b) input this is the
    same as quantize_blocks with base_idx = 0.
    """
    return quantize_blocks(v, m_bits, rmode, seed.astype(jnp.uint32), jnp.uint32(0))
