"""L2 step functions: loss, optimizers, train/eval/decode.

Each function here is a *whole-step* jax function lowered once by aot.py —
forward, backward, and the optimizer update fuse into a single HLO module
so the rust hot loop is exactly one PJRT execute per step (no per-layer
host round-trips; see DESIGN.md §7 L2).

Flat calling convention (the manifest records it):

  train_step(*params, *opt_state, x, y,
             bits_mid, bits_edge, rmode_grad, seed, lr)
      -> (*params', *opt_state', loss, metric)

  eval_batch(*params, x, y, bits_mid, bits_edge, rmode_grad, seed)
      -> (loss, metric)

  decode_greedy(*params, src, bits_mid, bits_edge, rmode_grad, seed)
      -> tokens                              (transformer only)

Optimizers follow the paper's recipes (Appendix A): SGD + Nesterov
momentum 0.9 / weight-decay 1e-4 for the CNN/MLP family, Adam(0.9, 0.98)
with weight decay 1e-4 for the transformer. Weight decay applies to rank>=2
tensors only (weights, not biases/norm scales), the standard convention.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp

from .hbfp import HbfpContext, softmax_xent
from .models.common import ModelDef, Scalars

MOMENTUM = 0.9
WEIGHT_DECAY = 1e-4
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.98, 1e-9


@dataclasses.dataclass
class OptSpec:
    kind: str  # "sgdm" | "adam"
    slot_names: List[str]
    slot_shapes: List[tuple]

    def to_json(self) -> dict:
        return {
            "kind": self.kind,
            "momentum": MOMENTUM,
            "weight_decay": WEIGHT_DECAY,
            "adam_betas": [ADAM_B1, ADAM_B2],
            "slots": [
                {"name": n, "shape": list(s)}
                for n, s in zip(self.slot_names, self.slot_shapes)
            ],
        }


def opt_spec(model: ModelDef, kind: str) -> OptSpec:
    names, shapes = [], []
    if kind == "sgdm":
        for s in model.builder.specs:
            names.append(f"momentum.{s.name}")
            shapes.append(s.shape)
    elif kind == "adam":
        for prefix in ("adam_m", "adam_v"):
            for s in model.builder.specs:
                names.append(f"{prefix}.{s.name}")
                shapes.append(s.shape)
        names.append("adam_t")
        shapes.append(())
    else:
        raise ValueError(kind)
    return OptSpec(kind, names, shapes)


def _decay_mask(params: Sequence[jax.Array]) -> List[bool]:
    return [p.ndim >= 2 for p in params]


def _sgdm_update(params, grads, bufs, lr):
    """PyTorch-style SGD with Nesterov momentum + decoupled-into-grad wd."""
    new_p, new_b = [], []
    for p, g, b, wd in zip(params, grads, bufs, _decay_mask(params)):
        g = g + WEIGHT_DECAY * p if wd else g
        b2 = MOMENTUM * b + g
        step = g + MOMENTUM * b2  # nesterov
        new_p.append(p - lr * step)
        new_b.append(b2)
    return new_p, new_b


def _adam_update(params, grads, ms, vs, t, lr):
    t2 = t + 1.0
    bc1 = 1.0 - ADAM_B1**t2
    bc2 = 1.0 - ADAM_B2**t2
    new_p, new_m, new_v = [], [], []
    for p, g, m, v, wd in zip(params, grads, ms, vs, _decay_mask(params)):
        g = g + WEIGHT_DECAY * p if wd else g
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(m2)
        new_v.append(v2)
    return new_p, new_m, new_v, t2


def _loss_and_metric(model: ModelDef, params, x, y, scalars: Scalars, ctx):
    logits = model.forward(params, x, scalars, ctx)
    if model.name == "transformer":
        # y holds next-token labels per position, -1 = don't score.
        mask = (y >= 0).astype(jnp.float32)
        labels = jnp.maximum(y, 0)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum((logz - gold) * mask) / denom
        acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
        return loss, acc
    loss = softmax_xent(logits, y)
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc


def make_fns(model: ModelDef, block: int, opt_kind: str, qflat):
    """Build (train_step, eval_batch) flat-argument functions."""
    n_params = len(model.builder.specs)
    ospec = opt_spec(model, opt_kind)
    n_opt = len(ospec.slot_names)

    def split(args):
        params = list(args[:n_params])
        opt = list(args[n_params : n_params + n_opt])
        rest = args[n_params + n_opt :]
        return params, opt, rest

    def train_step(*args):
        params, opt, rest = split(args)
        x, y, bits_mid, bits_edge, rmode_grad, seed, lr = rest
        scalars = Scalars(bits_mid, bits_edge, rmode_grad, seed)

        def loss_fn(ps):
            ctx = HbfpContext(block, qflat)
            loss, acc = _loss_and_metric(model, ps, x, y, scalars, ctx)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if opt_kind == "sgdm":
            new_p, bufs = _sgdm_update(params, grads, opt, lr)
            new_opt = bufs
        else:
            ms, vs, t = opt[:n_params], opt[n_params : 2 * n_params], opt[-1]
            new_p, m2, v2, t2 = _adam_update(params, grads, ms, vs, t, lr)
            new_opt = m2 + v2 + [t2]
        return tuple(new_p) + tuple(new_opt) + (loss, acc)

    def eval_batch(*args):
        params = list(args[:n_params])
        x, y, bits_mid, bits_edge, rmode_grad, seed = args[n_params:]
        scalars = Scalars(bits_mid, bits_edge, rmode_grad, seed)
        ctx = HbfpContext(block, qflat)
        loss, acc = _loss_and_metric(model, params, x, y, scalars, ctx)
        return loss, acc

    return train_step, eval_batch, ospec


def make_decode(model: ModelDef, block: int, qflat):
    """Greedy decode for the transformer: src -> generated tgt + EOS.

    Builds `[BOS] src [SEP] 0...` and fills positions left-to-right with
    argmax; the whole loop is a single lax.fori_loop inside one HLO module.
    """
    hp = model.hyper
    src_len, tgt_len, vocab = hp["src_len"], hp["tgt_len"], hp["vocab"]
    L = src_len + tgt_len + 3
    BOS, SEP = vocab - 6 + 0, vocab - 6 + 1  # ids 26, 27 for vocab=32

    def decode(*args):
        params = list(args[: len(model.builder.specs)])
        src, bits_mid, bits_edge, rmode_grad, seed = args[len(params) :]
        scalars = Scalars(bits_mid, bits_edge, rmode_grad, seed)
        B = src.shape[0]
        buf = jnp.full((B, L), 0, jnp.int32)
        buf = buf.at[:, 0].set(BOS)
        buf = buf.at[:, 1 : 1 + src_len].set(src)
        buf = buf.at[:, 1 + src_len].set(SEP)
        start = 2 + src_len  # first generated position

        def body(i, buf):
            ctx = HbfpContext(block, qflat)
            logits = model.forward(params, buf, scalars, ctx)
            nxt = jnp.argmax(logits[:, start + i - 1, :], axis=-1).astype(jnp.int32)
            return buf.at[:, start + i].set(nxt)

        buf = jax.lax.fori_loop(0, tgt_len + 1, body, buf)
        return (buf[:, start:],)

    return decode
