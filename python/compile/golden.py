"""Golden-vector generation pinning the BFP numerics contract.

Emits ``artifacts/golden_bfp.json``: inputs + bit-exact expected outputs of
``ref.quantize_flat`` across mantissa widths, block sizes, rounding modes,
seeds and padding edge cases. ``rust/src/bfp/tests`` replays these and must
match exactly (every f32 is exactly representable as a JSON double, so the
round-trip is lossless).
"""

from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from .kernels import ref as R


def _case(rng, n, block, m, rmode, seed, site, scale):
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    # Sprinkle exact zeros / tiny values / exact powers of two to pin the
    # edge cases (zero blocks, denormal guard, exponent extraction).
    if n >= 8:
        x[0] = 0.0
        x[1] = 2.0**-130  # denormal
        x[2] = -1.0
        x[3] = 0.5
        x[4] = 2.0**10
    out = R.quantize_flat(
        jnp.asarray(x),
        block,
        jnp.float32(m),
        jnp.float32(rmode),
        jnp.float32(seed),
        site,
    )
    return {
        "n": n,
        "block": block,
        "m_bits": m,
        "rmode": rmode,
        "seed": seed,
        "site": site,
        "input": [float(v) for v in x],
        "output": [float(v) for v in np.asarray(out)],
    }


def generate() -> dict:
    rng = np.random.default_rng(20260710)
    cases = []
    for block in (16, 25, 64, 576):
        for m in (4, 5, 6, 8, 24):
            for rmode in (0, 1):
                cases.append(_case(rng, 3 * block + 7, block, m, rmode, 7, 0, 1.0))
    # Extra shapes: shorter than one block, widely scaled, all-zero.
    cases.append(_case(rng, 9, 64, 4, 0, 7, 3, 1e-3))
    cases.append(_case(rng, 130, 49, 6, 1, 12345, 2, 100.0))
    zero = {
        "n": 32,
        "block": 16,
        "m_bits": 4,
        "rmode": 0,
        "seed": 0,
        "site": 0,
        "input": [0.0] * 32,
        "output": [0.0] * 32,
    }
    cases.append(zero)
    # Hash vectors for the xorshift stream itself.
    idx = np.arange(64, dtype=np.uint32)
    hashes = {
        str(seed): [int(v) for v in np.asarray(R.xorshift_hash(jnp.asarray(idx), jnp.uint32(seed)))]
        for seed in (0, 7, 12345)
    }
    return {"cases": cases, "xorshift": hashes}


def write(path: str) -> None:
    with open(path, "w") as f:
        json.dump(generate(), f)


if __name__ == "__main__":
    import sys

    write(sys.argv[1] if len(sys.argv) > 1 else "golden_bfp.json")
