"""Shared model-building machinery.

Parameters are a *flat ordered list* of f32 arrays; the order is the
contract between the AOT artifacts and the rust runtime (recorded in
manifest.json). Initialization happens in rust (so each run can seed its
own weights without touching python); the specs below carry everything the
initializer needs: shape + init kind + the numeric std/bound.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Sequence

import jax
import numpy as np


class InitKind:
    """Init kinds understood by rust/src/coordinator/init.rs."""

    ZEROS = "zeros"
    ONES = "ones"
    NORMAL = "normal"  # N(0, std^2)
    UNIFORM = "uniform"  # U(-bound, bound)


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple
    init: str = InitKind.NORMAL
    scale: float = 0.02  # std for NORMAL, bound for UNIFORM

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "init": self.init,
            "scale": self.scale,
        }


@dataclasses.dataclass
class Scalars:
    """Runtime scalars threaded into every forward/backward.

    The rust PrecisionScheduler drives these per step:
      bits_mid   mantissa width for middle layers' dots
      bits_edge  mantissa width for first/last layers' dots
      rmode_grad 0 = nearest-even, 1 = stochastic (gradients only)
      seed       stochastic-rounding stream seed (integer-valued f32)
    """

    bits_mid: jax.Array
    bits_edge: jax.Array
    rmode_grad: jax.Array
    seed: jax.Array

    NAMES = ("bits_mid", "bits_edge", "rmode_grad", "seed")

    @staticmethod
    def from_list(xs: Sequence[jax.Array]) -> "Scalars":
        return Scalars(*xs)


class ParamBuilder:
    """Registers parameter specs during model construction and resolves
    them positionally at trace time."""

    def __init__(self) -> None:
        self.specs: List[ParamSpec] = []
        self._index: Dict[str, int] = {}

    def add(self, name: str, shape: tuple, init: str, scale: float = 0.0) -> int:
        if name in self._index:
            raise ValueError(f"duplicate param {name}")
        idx = len(self.specs)
        self.specs.append(ParamSpec(name, tuple(shape), init, scale))
        self._index[name] = idx
        return idx

    def he_conv(self, name: str, kh: int, kw: int, cin: int, cout: int) -> int:
        # He init (paper Appendix A.1): std = sqrt(2 / n_out_activations),
        # with n = kh*kw*cout fan-out as in He et al. 2015.
        std = math.sqrt(2.0 / (kh * kw * cout))
        return self.add(name, (kh, kw, cin, cout), InitKind.NORMAL, std)

    def xavier(self, name: str, fan_in: int, fan_out: int) -> int:
        bound = math.sqrt(6.0 / (fan_in + fan_out))
        return self.add(name, (fan_in, fan_out), InitKind.UNIFORM, bound)

    def zeros(self, name: str, shape: tuple) -> int:
        return self.add(name, shape, InitKind.ZEROS)

    def ones(self, name: str, shape: tuple) -> int:
        return self.add(name, shape, InitKind.ONES)

    def normal(self, name: str, shape: tuple, std: float) -> int:
        return self.add(name, shape, InitKind.NORMAL, std)

    def get(self, params: Sequence[jax.Array], name: str) -> jax.Array:
        return params[self._index[name]]

    def init_numpy(self, seed: int) -> List[np.ndarray]:
        """Python-side initializer (tests / smoke training only; the rust
        runtime uses its own RNG with the same specs)."""
        rng = np.random.default_rng(seed)
        out = []
        for s in self.specs:
            if s.init == InitKind.ZEROS:
                out.append(np.zeros(s.shape, np.float32))
            elif s.init == InitKind.ONES:
                out.append(np.ones(s.shape, np.float32))
            elif s.init == InitKind.NORMAL:
                out.append(rng.normal(0.0, s.scale, s.shape).astype(np.float32))
            elif s.init == InitKind.UNIFORM:
                out.append(rng.uniform(-s.scale, s.scale, s.shape).astype(np.float32))
            else:
                raise ValueError(s.init)
        return out


@dataclasses.dataclass
class ModelDef:
    """Everything aot.py needs to lower one model family."""

    name: str
    builder: ParamBuilder
    forward: Callable  # (params, x, scalars: Scalars, ctx) -> logits
    input_shape: tuple  # per-example input shape (images: HWC; text: (L,))
    input_dtype: str  # "f32" | "i32"
    label_shape: tuple  # per-example label shape
    num_classes: int
    hyper: dict  # free-form hp record for the manifest
