"""L2 model zoo: MLP, ResNet-mini CNN, decoder-only Transformer.

Every model exposes:
  * ``HP`` hyperparameter dataclass,
  * ``build(hp)`` -> ``ModelDef`` with the ordered parameter specs
    (name/shape/init — consumed by the rust initializer via manifest.json)
    and a ``forward(params, x, scalars, ctx)`` callable where every dot
    product routes through the HBFP context.
"""

from .common import InitKind, ModelDef, ParamBuilder, ParamSpec, Scalars

__all__ = ["InitKind", "ModelDef", "ParamBuilder", "ParamSpec", "Scalars"]
