"""MLP classifier — the fast model for the full Table-1 format x block
sweep and for the Pallas-quantizer flagship artifact.

Layer taxonomy for the layer-aware policy: the input projection and the
classifier head are *edge* layers (bits_edge), the hidden projections are
*middle* layers (bits_mid) — the MLP analogue of "first conv / last fc".
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..hbfp import HbfpContext
from .common import ModelDef, ParamBuilder, Scalars


@dataclasses.dataclass
class HP:
    in_dim: int = 48  # 4x4x3 synthetic patches, flattened
    hidden: int = 96
    depth: int = 2  # number of hidden layers
    classes: int = 10


def build(hp: HP) -> ModelDef:
    pb = ParamBuilder()
    dims = [hp.in_dim] + [hp.hidden] * hp.depth + [hp.classes]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        pb.xavier(f"fc{i}.weight", a, b)
        pb.zeros(f"fc{i}.bias", (b,))
    n_layers = len(dims) - 1

    def forward(params, x, scalars: Scalars, ctx: HbfpContext):
        h = x.reshape(x.shape[0], -1)
        for i in range(n_layers):
            w = pb.get(params, f"fc{i}.weight")
            b = pb.get(params, f"fc{i}.bias")
            edge = i == 0 or i == n_layers - 1
            bits = scalars.bits_edge if edge else scalars.bits_mid
            h = ctx.linear(h, w, b, bits, scalars.rmode_grad, scalars.seed)
            if i != n_layers - 1:
                h = jnp.maximum(h, 0.0)
        return h

    return ModelDef(
        name="mlp",
        builder=pb,
        forward=forward,
        input_shape=(hp.in_dim,),
        input_dtype="f32",
        label_shape=(),
        num_classes=hp.classes,
        hyper=dataclasses.asdict(hp),
    )
