"""ResNet-mini — the CIFAR-family CNN stand-in (DESIGN.md §3).

Same layer taxonomy as the paper's ResNet20/50/74: a first 3x3 conv (edge),
a stack of residual stages (middle: all convs including 1x1 downsample
skips), global average pooling, and a final fully-connected classifier
(edge). BatchNorm weights stay FP32, initialized to 1 (paper Appendix A.1).

``HP.blocks_per_stage`` scales depth: 1 -> "ResNet8-mini", 2 ->
"ResNet14-mini", 3 -> "ResNet20-mini" — the knob the Table-1/2 harness uses
to emulate the paper's model-size axis.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..hbfp import HbfpContext, batchnorm, conv2d_im2col
from .common import ModelDef, ParamBuilder, Scalars


@dataclasses.dataclass
class HP:
    image: int = 16  # input is image x image x 3
    base_channels: int = 16
    blocks_per_stage: int = 2
    stages: int = 2  # channel doubling + stride-2 per extra stage
    classes: int = 10


def build(hp: HP) -> ModelDef:
    pb = ParamBuilder()
    pb.he_conv("conv1.weight", 3, 3, 3, hp.base_channels)
    pb.ones("bn1.gamma", (hp.base_channels,))
    pb.zeros("bn1.beta", (hp.base_channels,))

    chans = [hp.base_channels * (2**s) for s in range(hp.stages)]
    for s, c in enumerate(chans):
        cin = chans[s - 1] if s > 0 else hp.base_channels
        for b in range(hp.blocks_per_stage):
            bc_in = cin if b == 0 else c
            p = f"stage{s}.block{b}"
            pb.he_conv(f"{p}.conv1.weight", 3, 3, bc_in, c)
            pb.ones(f"{p}.bn1.gamma", (c,))
            pb.zeros(f"{p}.bn1.beta", (c,))
            pb.he_conv(f"{p}.conv2.weight", 3, 3, c, c)
            pb.ones(f"{p}.bn2.gamma", (c,))
            pb.zeros(f"{p}.bn2.beta", (c,))
            if bc_in != c:
                pb.he_conv(f"{p}.down.weight", 1, 1, bc_in, c)

    pb.xavier("fc.weight", chans[-1], hp.classes)
    pb.zeros("fc.bias", (hp.classes,))

    def forward(params, x, scalars: Scalars, ctx: HbfpContext):
        g = lambda n: pb.get(params, n)
        mid, edge = scalars.bits_mid, scalars.bits_edge
        rm, seed = scalars.rmode_grad, scalars.seed

        # First conv: edge precision (paper §2/§3).
        h = conv2d_im2col(ctx, x, g("conv1.weight"), edge, rm, seed)
        h = jnp.maximum(batchnorm(h, g("bn1.gamma"), g("bn1.beta")), 0.0)

        for s, c in enumerate(chans):
            cin = chans[s - 1] if s > 0 else hp.base_channels
            for b in range(hp.blocks_per_stage):
                bc_in = cin if b == 0 else c
                stride = 2 if (s > 0 and b == 0) else 1
                p = f"stage{s}.block{b}"
                y = conv2d_im2col(ctx, h, g(f"{p}.conv1.weight"), mid, rm, seed, stride)
                y = jnp.maximum(batchnorm(y, g(f"{p}.bn1.gamma"), g(f"{p}.bn1.beta")), 0.0)
                y = conv2d_im2col(ctx, y, g(f"{p}.conv2.weight"), mid, rm, seed)
                y = batchnorm(y, g(f"{p}.bn2.gamma"), g(f"{p}.bn2.beta"))
                skip = h
                if bc_in != c:
                    skip = conv2d_im2col(
                        ctx, h, g(f"{p}.down.weight"), mid, rm, seed, stride
                    )
                h = jnp.maximum(y + skip, 0.0)

        h = jnp.mean(h, axis=(1, 2))  # global average pool, FP32
        # Classifier head: edge precision.
        return ctx.linear(h, g("fc.weight"), g("fc.bias"), edge, rm, seed)

    return ModelDef(
        name="cnn",
        builder=pb,
        forward=forward,
        input_shape=(hp.image, hp.image, 3),
        input_dtype="f32",
        label_shape=(),
        num_classes=hp.classes,
        hyper=dataclasses.asdict(hp),
    )
