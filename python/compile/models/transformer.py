"""Decoder-only Transformer for the synthetic translation task (Table 3).

The paper trains an encoder-decoder Transformer-Base on IWSLT'14 De-En; we
substitute a decoder-only seq2seq over `[BOS] src [SEP] tgt [EOS]` on the
deterministic transduction grammar from rust/src/data/synth_text.rs
(DESIGN.md §3) — the same arithmetic profile (attention + FFN matmuls) and
the same metric (BLEU via greedy decode).

Layer taxonomy: the token embedding (a gather, FP32 — not a dot product)
and the output projection are the paper's "first/last layers"; the output
projection therefore runs at bits_edge, every other matmul (QKV/out
projections, attention scores, attention-context, FFN) at bits_mid.
Dropout is omitted (deterministic synthetic task; documented substitution).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..hbfp import HbfpContext, layernorm
from .common import ModelDef, ParamBuilder, Scalars


@dataclasses.dataclass
class HP:
    vocab: int = 32  # ids 0..25 payload, 26=BOS, 27=SEP, 28=EOS, 29=PAD
    src_len: int = 8
    tgt_len: int = 8
    d_model: int = 64
    heads: int = 4
    layers: int = 2
    d_ff: int = 128

    @property
    def seq_len(self) -> int:
        # [BOS] src [SEP] tgt [EOS]
        return self.src_len + self.tgt_len + 3


def build(hp: HP) -> ModelDef:
    pb = ParamBuilder()
    d, L = hp.d_model, hp.seq_len
    pb.normal("embed.weight", (hp.vocab, d), std=d**-0.5)
    pb.normal("pos.weight", (L, d), std=0.02)
    for i in range(hp.layers):
        p = f"layer{i}"
        pb.ones(f"{p}.ln1.gamma", (d,))
        pb.zeros(f"{p}.ln1.beta", (d,))
        for nm in ("q", "k", "v", "o"):
            pb.xavier(f"{p}.attn.{nm}.weight", d, d)
        pb.ones(f"{p}.ln2.gamma", (d,))
        pb.zeros(f"{p}.ln2.beta", (d,))
        pb.xavier(f"{p}.ffn.w1", d, hp.d_ff)
        pb.zeros(f"{p}.ffn.b1", (hp.d_ff,))
        pb.xavier(f"{p}.ffn.w2", hp.d_ff, d)
        pb.zeros(f"{p}.ffn.b2", (d,))
    pb.ones("ln_f.gamma", (d,))
    pb.zeros("ln_f.beta", (d,))
    pb.xavier("out.weight", d, hp.vocab)

    dh = d // hp.heads
    neg_inf = jnp.float32(-1e9)

    def forward(params, tokens, scalars: Scalars, ctx: HbfpContext):
        g = lambda n: pb.get(params, n)
        mid, edge = scalars.bits_mid, scalars.bits_edge
        rm, seed = scalars.rmode_grad, scalars.seed
        B = tokens.shape[0]

        h = g("embed.weight")[tokens] + g("pos.weight")[None, :, :]
        causal = jnp.tril(jnp.ones((L, L), jnp.float32))

        def proj(x2d, name):
            return ctx.dot(x2d, g(name), mid, rm, seed)

        for i in range(hp.layers):
            p = f"layer{i}"
            x = layernorm(h, g(f"{p}.ln1.gamma"), g(f"{p}.ln1.beta"))
            x2 = x.reshape(B * L, d)
            q = proj(x2, f"{p}.attn.q.weight").reshape(B, L, hp.heads, dh)
            k = proj(x2, f"{p}.attn.k.weight").reshape(B, L, hp.heads, dh)
            v = proj(x2, f"{p}.attn.v.weight").reshape(B, L, hp.heads, dh)
            # [B*H, L, dh]
            q = q.transpose(0, 2, 1, 3).reshape(B * hp.heads, L, dh)
            k = k.transpose(0, 2, 1, 3).reshape(B * hp.heads, L, dh)
            v = v.transpose(0, 2, 1, 3).reshape(B * hp.heads, L, dh)
            # Attention scores and context are dot products too -> HBFP.
            scores = ctx.batched_dot(q, k.transpose(0, 2, 1), mid, rm, seed)
            scores = scores * jnp.float32(dh**-0.5)
            scores = jnp.where(causal[None] > 0.5, scores, neg_inf)
            probs = jax.nn.softmax(scores, axis=-1)  # FP32
            cx = ctx.batched_dot(probs, v, mid, rm, seed)
            cx = cx.reshape(B, hp.heads, L, dh).transpose(0, 2, 1, 3).reshape(B * L, d)
            h = h + proj(cx, f"{p}.attn.o.weight").reshape(B, L, d)

            x = layernorm(h, g(f"{p}.ln2.gamma"), g(f"{p}.ln2.beta"))
            y = ctx.linear(x.reshape(B * L, d), g(f"{p}.ffn.w1"), g(f"{p}.ffn.b1"), mid, rm, seed)
            y = jnp.maximum(y, 0.0)
            y = ctx.linear(y, g(f"{p}.ffn.w2"), g(f"{p}.ffn.b2"), mid, rm, seed)
            h = h + y.reshape(B, L, d)

        h = layernorm(h, g("ln_f.gamma"), g("ln_f.beta"))
        # Output projection: edge precision (paper keeps first/last layers
        # at HBFP6 under the Booster schedule).
        logits = ctx.dot(h.reshape(B * L, d), g("out.weight"), edge, rm, seed)
        return logits.reshape(B, L, hp.vocab)

    return ModelDef(
        name="transformer",
        builder=pb,
        forward=forward,
        input_shape=(L,),
        input_dtype="i32",
        label_shape=(L,),
        num_classes=hp.vocab,
        hyper=dataclasses.asdict(hp),
    )
