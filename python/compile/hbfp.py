"""L2 plumbing — HBFP dot products with custom VJP.

HBFP rule (Drumond et al., and §2 of the paper): *every* dot-product
operand — activations, weights and gradients, in both the forward and the
backward pass — is converted to BFP with blocks along the contraction
dimension; everything else (norms, softmax, residual adds, optimizer math)
stays FP32.

``hbfp_dot`` implements that with a custom VJP:

    fwd:  y  = Q_K(x)  @ Q_K(w)            (round-to-nearest-even)
    bwd:  dx = Q_N(g)  @ Q_N(w)ᵀ           (rounding mode = rmode_grad,
          dw = Q_M(x)ᵀ @ Q_M(g)             0 = nearest, 1 = stochastic)

Mantissa width, gradient rounding mode and the stochastic-rounding seed are
traced scalars: the rust coordinator flips them per epoch (the Accuracy
Booster schedule) without recompiling the AOT artifact.

``site`` is a static per-call-site salt so every quantizer invocation draws
an independent stochastic-rounding stream. Each dot consumes SITE_STRIDE
slots. The quantizer itself is pluggable: the plain jnp reference or the
Pallas kernel (``aot.py --pallas``) — they are bit-identical (pytest).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import ref as R

# Each hbfp_dot uses sites [site, site + SITE_STRIDE) for its six
# quantizer invocations (2 fwd + 4 bwd).
SITE_STRIDE = 8

QuantFlatFn = Callable[..., jax.Array]

# f32 scalar constants for the rounding-mode argument.
NEAREST = jnp.float32(0.0)


def quantize_along_axis(
    qflat: QuantFlatFn,
    t: jax.Array,
    axis: int,
    block: int,
    m_bits: jax.Array,
    rmode: jax.Array,
    seed: jax.Array,
    site: int,
) -> jax.Array:
    """Move ``axis`` last, quantize row-major blocks with ``qflat``."""
    moved = jnp.moveaxis(t, axis, -1)
    q = qflat(moved, block, m_bits, rmode, seed, site)
    return jnp.moveaxis(q, -1, axis)


def make_hbfp_dot(block: int, site: int, qflat: QuantFlatFn = R.quantize_flat):
    """Build the custom-VJP HBFP matmul for one call site.

    Returns ``dot(x, w, m_bits, rmode_grad, seed) -> y`` for x:[M,K],
    w:[K,N]. ``block`` and ``site`` are static; the scalars are traced.
    """

    def _fwd_value(x, w, m_bits, rmode_grad, seed):
        del rmode_grad
        xq = qflat(x, block, m_bits, NEAREST, seed, site)
        wq = quantize_along_axis(qflat, w, 0, block, m_bits, NEAREST, seed, site + 1)
        return jnp.dot(xq, wq, preferred_element_type=jnp.float32)

    @jax.custom_vjp
    def hbfp_dot(x, w, m_bits, rmode_grad, seed):
        return _fwd_value(x, w, m_bits, rmode_grad, seed)

    def fwd(x, w, m_bits, rmode_grad, seed):
        y = _fwd_value(x, w, m_bits, rmode_grad, seed)
        return y, (x, w, m_bits, rmode_grad, seed)

    def bwd(res, g):
        x, w, m_bits, rmode_grad, seed = res
        # dx = Q(g) @ Q(w)^T, contraction (and blocks) along N.
        gq_n = qflat(g, block, m_bits, rmode_grad, seed, site + 2)
        wq_n = quantize_along_axis(
            qflat, w, 1, block, m_bits, rmode_grad, seed, site + 3
        )
        dx = jnp.dot(gq_n, wq_n.T, preferred_element_type=jnp.float32)
        # dw = Q(x)^T @ Q(g), contraction (and blocks) along M.
        xq_m = quantize_along_axis(
            qflat, x, 0, block, m_bits, rmode_grad, seed, site + 4
        )
        gq_m = quantize_along_axis(
            qflat, g, 0, block, m_bits, rmode_grad, seed, site + 5
        )
        dw = jnp.dot(xq_m.T, gq_m, preferred_element_type=jnp.float32)
        zero = jnp.zeros_like(m_bits)
        return dx, dw, zero, jnp.zeros_like(rmode_grad), jnp.zeros_like(seed)

    hbfp_dot.defvjp(fwd, bwd)
    return hbfp_dot


class SiteAllocator:
    """Hands out static stochastic-rounding site salts during model build."""

    def __init__(self) -> None:
        self._next = 0

    def alloc(self) -> int:
        s = self._next
        self._next += SITE_STRIDE
        return s


class HbfpContext:
    """Per-model-build context: block size, quantizer flavour, site salts.

    Models never call the quantizer directly; they go through ``dot`` /
    ``batched_dot`` so that every dot product in fwd *and* bwd follows the
    HBFP rule with a unique rounding stream.
    """

    def __init__(self, block: int, qflat: QuantFlatFn = R.quantize_flat) -> None:
        self.block = block
        self.qflat = qflat
        self.sites = SiteAllocator()

    def dot(self, x: jax.Array, w: jax.Array, m_bits, rmode_grad, seed) -> jax.Array:
        """HBFP matmul for 2-D ``x`` [M,K] @ ``w`` [K,N]."""
        fn = make_hbfp_dot(self.block, self.sites.alloc(), self.qflat)
        return fn(x, w, m_bits, rmode_grad, seed)

    def batched_dot(self, x: jax.Array, w: jax.Array, m_bits, rmode_grad, seed):
        """HBFP matmul with leading batch dims on both operands.

        x: [..., M, K], w: [..., K, N] with identical leading dims (used by
        attention: scores = Q @ Kᵀ and ctx = P @ V per (batch, head)).
        """
        fn = make_hbfp_dot(self.block, self.sites.alloc(), self.qflat)
        lead = x.shape[:-2]
        xm = x.reshape((-1,) + x.shape[-2:])
        wm = w.reshape((-1,) + w.shape[-2:])
        out = jax.vmap(lambda a, b: fn(a, b, m_bits, rmode_grad, seed))(xm, wm)
        return out.reshape(lead + out.shape[-2:])

    def linear(self, x, w, b, m_bits, rmode_grad, seed):
        """Affine layer: HBFP dot + FP32 bias."""
        y = self.dot(x, w, m_bits, rmode_grad, seed)
        return y if b is None else y + b


# ---------------------------------------------------------------------------
# FP32 building blocks (the "H" in HBFP — never quantized)
# ---------------------------------------------------------------------------


def conv2d_im2col(
    ctx: HbfpContext,
    x: jax.Array,  # [B, H, W, Cin]  NHWC
    w: jax.Array,  # [kh, kw, Cin, Cout]
    m_bits,
    rmode_grad,
    seed,
    stride: int = 1,
) -> jax.Array:
    """Convolution lowered to im2col + HBFP matmul (SAME padding).

    This mirrors how an HBFP accelerator executes convs: the im2col stream
    feeds the blocked fixed-point dot-product array, blocks running along
    K = kh*kw*Cin.
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, H', W', Cin*kh*kw]
    b, ho, wo, k = patches.shape
    # conv_general_dilated_patches orders features as (Cin, kh, kw); align
    # the weight layout to match before flattening to [K, Cout].
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(kh * kw * cin, cout)
    y = ctx.dot(patches.reshape(b * ho * wo, k), wmat, m_bits, rmode_grad, seed)
    return y.reshape(b, ho, wo, cout)


def batchnorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    """Batch-statistics norm over all axes but the channel (last) axis.

    FP32 per HBFP; uses batch stats in both train and eval (no running
    averages — eval batches are the same size, see DESIGN.md §3).
    """
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes, keepdims=True)
    var = jnp.var(x, axis=axes, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + eps)
    return xhat * gamma + beta


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma + beta


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy; labels are int class ids."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
