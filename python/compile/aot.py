"""AOT compiler: lower every model variant to HLO text + manifest.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 rust crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact directory per variant ``<model>_bs<block>[_pallas]``:

    artifacts/<variant>/train_step.hlo.txt
    artifacts/<variant>/eval.hlo.txt
    artifacts/<variant>/decode.hlo.txt      (transformer only)
    artifacts/<variant>/manifest.json

plus ``artifacts/index.json`` (variant registry) and
``artifacts/golden_bfp.json`` (the rust<->python numerics contract).

Block size is baked per artifact (it changes padded shapes); mantissa
widths / rounding mode / seed / lr stay runtime scalars so the rust
PrecisionScheduler drives the whole format sweep and the Accuracy Booster
schedule from a handful of artifacts.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import golden, train
from .kernels import bfp_pallas
from .kernels import ref as R
from .models import cnn, mlp, transformer
from .models.common import ModelDef

# The paper's block-size axis (Table 1 / Fig 1 / Fig 6).
PAPER_BLOCK_SIZES = (16, 25, 36, 49, 64, 256, 576)

BATCH = {"mlp": 128, "cnn": 64, "transformer": 32}
OPT = {"mlp": "sgdm", "cnn": "sgdm", "transformer": "adam"}


@dataclasses.dataclass
class Variant:
    model: str
    block: int
    pallas: bool = False

    @property
    def name(self) -> str:
        return f"{self.model}_bs{self.block}" + ("_pallas" if self.pallas else "")


def default_variants(quick: bool) -> List[Variant]:
    vs: List[Variant] = []
    blocks = (16, 64) if quick else PAPER_BLOCK_SIZES
    for b in blocks:
        vs.append(Variant("mlp", b))
        vs.append(Variant("cnn", b))
    vs.append(Variant("mlp", 64, pallas=True))  # flagship Pallas-kernel build
    vs.append(Variant("transformer", 64))
    return vs


def build_model(kind: str) -> ModelDef:
    if kind == "mlp":
        return mlp.build(mlp.HP())
    if kind == "cnn":
        return cnn.build(cnn.HP())
    if kind == "transformer":
        return transformer.build(transformer.HP())
    raise ValueError(kind)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32():
    return jax.ShapeDtypeStruct((), jnp.float32)


def lower_variant(v: Variant, out_dir: str) -> dict:
    model = build_model(v.model)
    qflat = bfp_pallas.quantize_flat_pallas if v.pallas else R.quantize_flat
    opt_kind = OPT[v.model]
    batch = BATCH[v.model]
    train_step, eval_batch, ospec = train.make_fns(model, v.block, opt_kind, qflat)

    in_dt = jnp.float32 if model.input_dtype == "f32" else jnp.int32
    x_spec = jax.ShapeDtypeStruct((batch,) + model.input_shape, in_dt)
    y_spec = jax.ShapeDtypeStruct((batch,) + model.label_shape, jnp.int32)
    p_specs = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.builder.specs]
    o_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in ospec.slot_shapes]

    vdir = os.path.join(out_dir, v.name)
    os.makedirs(vdir, exist_ok=True)

    train_args = p_specs + o_specs + [x_spec, y_spec] + [_f32()] * 5
    lowered = jax.jit(train_step).lower(*train_args)
    with open(os.path.join(vdir, "train_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    eval_args = p_specs + [x_spec, y_spec] + [_f32()] * 4
    lowered = jax.jit(eval_batch).lower(*eval_args)
    with open(os.path.join(vdir, "eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    artifacts = {"train_step": "train_step.hlo.txt", "eval": "eval.hlo.txt"}
    decode_info = None
    if v.model == "transformer":
        hp = model.hyper
        dec = train.make_decode(model, v.block, qflat)
        src_spec = jax.ShapeDtypeStruct((batch, hp["src_len"]), jnp.int32)
        lowered = jax.jit(dec).lower(*(p_specs + [src_spec] + [_f32()] * 4))
        with open(os.path.join(vdir, "decode.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        artifacts["decode"] = "decode.hlo.txt"
        decode_info = {
            "src_len": hp["src_len"],
            "tgt_len": hp["tgt_len"],
            "out_len": hp["tgt_len"] + 1,
            "bos": hp["vocab"] - 6,
            "sep": hp["vocab"] - 5,
            "eos": hp["vocab"] - 4,
        }

    manifest = {
        "variant": v.name,
        "model": v.model,
        "block": v.block,
        "pallas": v.pallas,
        "batch": batch,
        "input_shape": list(model.input_shape),
        "input_dtype": model.input_dtype,
        "label_shape": list(model.label_shape),
        "num_classes": model.num_classes,
        "hyper": model.hyper,
        "params": [s.to_json() for s in model.builder.specs],
        "opt": ospec.to_json(),
        "scalars_train": ["bits_mid", "bits_edge", "rmode_grad", "seed", "lr"],
        "scalars_eval": ["bits_mid", "bits_edge", "rmode_grad", "seed"],
        "artifacts": artifacts,
        "decode": decode_info,
    }
    with open(os.path.join(vdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return {"name": v.name, "model": v.model, "block": v.block, "pallas": v.pallas}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="default",
        help="comma list like cnn_bs64,mlp_bs16,transformer_bs64[_pallas] or 'default'",
    )
    ap.add_argument("--quick", action="store_true", help="small variant set for CI")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.variants == "default":
        variants = default_variants(args.quick)
    else:
        variants = []
        for tok in args.variants.split(","):
            pallas = tok.endswith("_pallas")
            core = tok[: -len("_pallas")] if pallas else tok
            m, bs = core.rsplit("_bs", 1)
            variants.append(Variant(m, int(bs), pallas))

    index = []
    for v in variants:
        print(f"[aot] lowering {v.name} ...", flush=True)
        index.append(lower_variant(v, args.out))

    golden.write(os.path.join(args.out, "golden_bfp.json"))
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"variants": index}, f, indent=1)
    print(f"[aot] wrote {len(index)} variants + golden vectors to {args.out}")


if __name__ == "__main__":
    main()
