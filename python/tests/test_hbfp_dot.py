"""custom-VJP correctness for hbfp_dot: fwd composition, bwd HBFP rule,
FP32-bypass gradients vs autodiff ground truth."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import hbfp
from compile.kernels import ref as R

F32 = jnp.float32
SC = dict(m_bits=F32(4), rmode=F32(0.0), seed=F32(7))


def _rand(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).standard_normal(shape) * scale).astype(np.float32)


def test_forward_equals_ref_composition():
    x, w = _rand((8, 48), 1), _rand((48, 16), 2)
    dot = hbfp.make_hbfp_dot(block=16, site=0)
    y = dot(jnp.asarray(x), jnp.asarray(w), F32(4), F32(0.0), F32(7))
    want = R.bfp_dot_ref(jnp.asarray(x), jnp.asarray(w), 16, F32(4), F32(0.0), F32(7), site=0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_bypass_gradients_match_exact_matmul():
    """With m_bits >= 23 the custom VJP must reduce to the exact matmul
    gradient — validates the transposes/blocking axes in bwd."""
    x, w = _rand((6, 32), 3), _rand((32, 10), 4)
    dot = hbfp.make_hbfp_dot(block=16, site=0)

    def f_hbfp(x, w):
        return jnp.sum(jnp.sin(dot(x, w, F32(24), F32(0.0), F32(7))))

    def f_exact(x, w):
        return jnp.sum(jnp.sin(x @ w))

    gx1, gw1 = jax.grad(f_hbfp, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    gx2, gw2 = jax.grad(f_exact, argnums=(0, 1))(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-5, atol=1e-5)


def test_bwd_quantizes_gradients():
    """At m=4 the backward result must equal the hand-built HBFP rule:
    dx = Q_N(g) @ Q_N(w)^T, dw = Q_M(x)^T @ Q_M(g)."""
    x, w = _rand((8, 24), 5), _rand((24, 12), 6)
    site, block, m = 0, 8, F32(4)
    dot = hbfp.make_hbfp_dot(block=block, site=site)

    y, vjp = jax.vjp(lambda a, b: dot(a, b, m, F32(0.0), F32(7)), jnp.asarray(x), jnp.asarray(w))
    g = _rand(y.shape, 7)
    dx, dw = vjp(jnp.asarray(g))

    qf = R.quantize_flat
    gq_n = qf(jnp.asarray(g), block, m, F32(0.0), F32(7), site + 2)
    wq_n = R.quantize_along_axis(jnp.asarray(w), 1, block, m, F32(0.0), F32(7), site + 3)
    want_dx = gq_n @ wq_n.T
    xq_m = R.quantize_along_axis(jnp.asarray(x), 0, block, m, F32(0.0), F32(7), site + 4)
    gq_m = R.quantize_along_axis(jnp.asarray(g), 0, block, m, F32(0.0), F32(7), site + 5)
    want_dw = xq_m.T @ gq_m

    np.testing.assert_allclose(np.asarray(dx), np.asarray(want_dx), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want_dw), rtol=1e-6, atol=1e-6)


def test_scalar_args_get_zero_grads():
    x, w = _rand((4, 16), 8), _rand((16, 4), 9)
    dot = hbfp.make_hbfp_dot(block=16, site=0)

    def f(bits):
        return jnp.sum(dot(jnp.asarray(x), jnp.asarray(w), bits, F32(0.0), F32(7)))

    assert float(jax.grad(f)(F32(6))) == 0.0


def test_batched_dot_matches_per_example():
    ctx = hbfp.HbfpContext(block=16)
    x = _rand((3, 8, 16), 10)
    w = _rand((3, 16, 8), 11)
    y = ctx.batched_dot(jnp.asarray(x), jnp.asarray(w), F32(6), F32(0.0), F32(7))
    ctx2 = hbfp.HbfpContext(block=16)
    fn = hbfp.make_hbfp_dot(16, ctx2.sites.alloc())
    for i in range(3):
        want = fn(jnp.asarray(x[i]), jnp.asarray(w[i]), F32(6), F32(0.0), F32(7))
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_stochastic_grad_rounding_depends_on_seed():
    x, w = _rand((8, 64), 12, 0.5), _rand((64, 8), 13, 0.5)
    dot = hbfp.make_hbfp_dot(block=64, site=0)

    def gx(seed):
        f = lambda a: jnp.sum(dot(a, jnp.asarray(w), F32(4), F32(1.0), seed) ** 2)
        return np.asarray(jax.grad(f)(jnp.asarray(x)))

    assert not np.array_equal(gx(F32(1)), gx(F32(2)))
    # and deterministic given the seed
    np.testing.assert_array_equal(gx(F32(1)), gx(F32(1)))


def test_conv_im2col_matches_lax_conv_in_bypass():
    """conv2d_im2col at m>=23 must equal lax.conv (SAME, NHWC)."""
    ctx = hbfp.HbfpContext(block=64)
    x = _rand((2, 8, 8, 3), 14)
    w = _rand((3, 3, 3, 5), 15)
    y = hbfp.conv2d_im2col(ctx, jnp.asarray(x), jnp.asarray(w), F32(24), F32(0.0), F32(7))
    want = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4)

    y2 = hbfp.conv2d_im2col(ctx, jnp.asarray(x), jnp.asarray(w), F32(24), F32(0.0), F32(7), stride=2)
    want2 = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w), (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(want2), rtol=1e-4, atol=1e-4)
