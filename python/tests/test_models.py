"""Model-level tests: shapes, trainability under HBFP, optimizer algebra,
decode plumbing. These run on tiny batches so the suite stays fast."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import train
from compile.kernels import ref as R
from compile.models import cnn, mlp, transformer
from compile.models.common import Scalars

F32 = jnp.float32
SC5 = [F32(6), F32(6), F32(0.0), F32(7), F32(0.05)]  # bits_mid, bits_edge, rmode, seed, lr


def _setup(kind, block=64):
    if kind == "mlp":
        model, opt = mlp.build(mlp.HP()), "sgdm"
    elif kind == "cnn":
        model, opt = cnn.build(cnn.HP()), "sgdm"
    else:
        model, opt = transformer.build(transformer.HP()), "adam"
    ts, ev, ospec = train.make_fns(model, block, opt, R.quantize_flat)
    params = [jnp.asarray(p) for p in model.builder.init_numpy(0)]
    opt_state = [jnp.zeros(s, F32) for s in ospec.slot_shapes]
    return model, ts, ev, ospec, params, opt_state


def _batch(model, B, seed=0):
    rng = np.random.default_rng(seed)
    if model.name == "transformer":
        L = model.input_shape[0]
        x = jnp.asarray(rng.integers(0, 26, (B, L)), jnp.int32)
        y = jnp.asarray(rng.integers(-1, 26, (B, L)), jnp.int32)
    else:
        x = jnp.asarray(rng.standard_normal((B,) + model.input_shape), F32)
        y = jnp.asarray(rng.integers(0, model.num_classes, (B,)), jnp.int32)
    return x, y


@pytest.mark.parametrize("kind", ["mlp", "cnn", "transformer"])
def test_train_step_shapes_roundtrip(kind):
    model, ts, ev, ospec, params, opt_state = _setup(kind)
    x, y = _batch(model, 4)
    out = jax.jit(ts)(*params, *opt_state, x, y, *SC5)
    assert len(out) == len(params) + len(opt_state) + 2
    for p, o in zip(params, out):
        assert p.shape == o.shape and o.dtype == jnp.float32
    loss, acc = out[-2], out[-1]
    assert loss.shape == () and acc.shape == ()
    assert np.isfinite(float(loss)) and 0.0 <= float(acc) <= 1.0


@pytest.mark.parametrize("kind,bits", [("mlp", 4), ("mlp", 6), ("cnn", 4)])
def test_loss_decreases_under_hbfp(kind, bits):
    model, ts, _, _, params, opt_state = _setup(kind)
    x, y = _batch(model, 16)
    sc = [F32(bits), F32(6), F32(1.0), F32(7), F32(0.05)]
    step = jax.jit(ts)
    args = params + opt_state
    first = None
    for i in range(8):
        out = step(*args, x, y, *sc[:3], F32(i), sc[4])
        args = list(out[:-2])
        if first is None:
            first = float(out[-2])
    assert float(out[-2]) < first * 0.9, (first, float(out[-2]))


def test_eval_matches_fresh_forward():
    model, ts, ev, ospec, params, opt_state = _setup("mlp")
    x, y = _batch(model, 8)
    loss, acc = jax.jit(ev)(*params, x, y, *SC5[:4])
    loss2, acc2 = jax.jit(ev)(*params, x, y, *SC5[:4])
    assert float(loss) == float(loss2) and float(acc) == float(acc2)
    assert np.isfinite(float(loss))


def test_sgdm_nesterov_update_algebra():
    """One step of the lowered optimizer == the hand equation."""
    model, ts, _, ospec, params, opt_state = _setup("mlp")
    x, y = _batch(model, 8)
    # FP32 bypass so grads are the exact autodiff grads.
    sc = [F32(24), F32(24), F32(0.0), F32(7), F32(0.1)]
    out = jax.jit(ts)(*params, *opt_state, x, y, *sc)
    new_params = out[: len(params)]
    new_bufs = out[len(params) : len(params) + len(opt_state)]

    def loss_fn(ps):
        from compile.hbfp import HbfpContext
        ctx = HbfpContext(64)
        scal = Scalars(sc[0], sc[1], sc[2], sc[3])
        l, _ = train._loss_and_metric(model, list(ps), x, y, scal, ctx)
        return l

    grads = jax.grad(loss_fn)(tuple(params))
    for p, g, b2, np_, nb in zip(params, grads, opt_state, new_params, new_bufs):
        wd = 0.0001 if p.ndim >= 2 else 0.0
        geff = g + wd * p
        buf = 0.9 * b2 + geff
        want_p = p - 0.1 * (geff + 0.9 * buf)
        np.testing.assert_allclose(np.asarray(np_), np.asarray(want_p), rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(nb), np.asarray(buf), rtol=2e-4, atol=2e-5)


def test_adam_t_counter_increments():
    model, ts, _, ospec, params, opt_state = _setup("transformer")
    x, y = _batch(model, 2)
    out = jax.jit(ts)(*params, *opt_state, x, y, *SC5)
    t = out[len(params) + len(opt_state) - 1]
    assert float(t) == 1.0
    out2 = jax.jit(ts)(*list(out[:-2]), x, y, *SC5)
    assert float(out2[len(params) + len(opt_state) - 1]) == 2.0


def test_decode_shapes_and_determinism():
    model = transformer.build(transformer.HP())
    dec = train.make_decode(model, 64, R.quantize_flat)
    params = [jnp.asarray(p) for p in model.builder.init_numpy(0)]
    hp = model.hyper
    rng = np.random.default_rng(1)
    src = jnp.asarray(rng.integers(0, 26, (4, hp["src_len"])), jnp.int32)
    (toks,) = jax.jit(dec)(*params, src, F32(6), F32(6), F32(0.0), F32(7))
    assert toks.shape == (4, hp["tgt_len"] + 1)
    assert toks.dtype == jnp.int32
    (toks2,) = jax.jit(dec)(*params, src, F32(6), F32(6), F32(0.0), F32(7))
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_edge_vs_mid_bits_actually_route():
    """Degrading only bits_mid must change the loss; same for bits_edge —
    proves the layer-aware wiring (first/last vs middle) is real."""
    model, ts, ev, _, params, _ = _setup("cnn")
    x, y = _batch(model, 4)
    base = float(jax.jit(ev)(*params, x, y, F32(24), F32(24), F32(0.0), F32(7))[0])
    mid2 = float(jax.jit(ev)(*params, x, y, F32(2), F32(24), F32(0.0), F32(7))[0])
    edge2 = float(jax.jit(ev)(*params, x, y, F32(24), F32(2), F32(0.0), F32(7))[0])
    assert mid2 != base
    assert edge2 != base


def test_param_manifest_consistency():
    for kind in ("mlp", "cnn", "transformer"):
        model, _, _, ospec, params, opt_state = _setup(kind)
        assert len(params) == len(model.builder.specs)
        for spec, p in zip(model.builder.specs, params):
            assert tuple(spec.shape) == tuple(p.shape)
        if kind == "transformer":
            assert ospec.slot_names[-1] == "adam_t"
            assert len(opt_state) == 2 * len(params) + 1
        else:
            assert len(opt_state) == len(params)
