"""Make `pytest python/tests/` work from the repo root: the test modules
import the build-time package as `compile.*`, which lives in python/."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
