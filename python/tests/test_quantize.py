"""Kernel-vs-oracle and quantizer-property tests (the core L1 signal).

Hypothesis sweeps shapes / mantissa widths / block sizes / rounding modes
and asserts the Pallas kernel is **bit-exact** against the pure-jnp
reference, plus the mathematical invariants Eq. 1 implies.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as R
from compile.kernels import bfp_pallas as P

F32 = jnp.float32


def q_ref(x, block, m, rmode=0.0, seed=7.0, site=0):
    return np.asarray(
        R.quantize_flat(jnp.asarray(x), block, F32(m), F32(rmode), F32(seed), site)
    )


def q_pallas(x, block, m, rmode=0.0, seed=7.0, site=0):
    return np.asarray(
        P.quantize_flat_pallas(jnp.asarray(x), block, F32(m), F32(rmode), F32(seed), site)
    )


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 700),
    block=st.sampled_from([4, 16, 25, 49, 64, 576]),
    m=st.sampled_from([2, 3, 4, 5, 6, 8, 12, 24]),
    rmode=st.sampled_from([0.0, 1.0]),
    seed=st.integers(0, 2**20),
    scale=st.sampled_from([1e-4, 1.0, 1e4]),
)
def test_pallas_matches_ref_bitexact(n, block, m, rmode, seed, scale):
    rng = np.random.default_rng(n * 31 + m)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    a = q_ref(x, block, m, rmode, float(seed))
    b = q_pallas(x, block, m, rmode, float(seed))
    assert a.dtype == np.float32
    np.testing.assert_array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 12),
    block=st.sampled_from([8, 16, 64]),
    m=st.sampled_from([3, 4, 6, 8]),
)
def test_error_bound_eq1(nb, block, m):
    """Nearest rounding error is at most interval/2 = 2^(e-m+1) per block,
    except for elements clipped at +2^(m-1)-1 (one extra interval)."""
    rng = np.random.default_rng(nb * 7 + block)
    x = rng.standard_normal((nb, block)).astype(np.float32)
    out = np.asarray(
        R.quantize_blocks(jnp.asarray(x), F32(m), F32(0.0), jnp.uint32(0), jnp.uint32(0))
    )
    for i in range(nb):
        e = np.floor(np.log2(np.abs(x[i]).max()))
        interval = 2.0 ** (e - m + 2)
        assert np.all(np.abs(out[i] - x[i]) <= interval * 1.0 + 1e-12)


def test_bypass_is_identity():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(333).astype(np.float32)
    np.testing.assert_array_equal(q_ref(x, 64, 24), x)
    np.testing.assert_array_equal(q_ref(x, 16, 32), x)


def test_zero_and_denormal_blocks():
    x = np.zeros(64, np.float32)
    np.testing.assert_array_equal(q_ref(x, 16, 4), x)
    x = np.full(64, 2.0**-135, np.float32)  # denormal max
    np.testing.assert_array_equal(q_ref(x, 16, 4), np.zeros(64, np.float32))


def test_idempotent_nearest():
    """Quantizing a quantized tensor with the same (m, b) is the identity —
    representable points are fixed points of the quantizer."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal(256).astype(np.float32)
    for m in (4, 6, 8):
        once = q_ref(x, 64, m)
        twice = q_ref(once, 64, m)
        np.testing.assert_array_equal(once, twice)


def test_error_monotone_in_mantissa():
    """More mantissa bits -> no larger L2 error (§2 of the paper)."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal(4096).astype(np.float32)
    errs = []
    for m in (2, 3, 4, 5, 6, 8, 10):
        errs.append(float(np.square(q_ref(x, 64, m) - x).sum()))
    assert all(a >= b - 1e-9 for a, b in zip(errs, errs[1:])), errs


def test_error_grows_with_block_size_for_small_mantissa():
    """Larger blocks -> more magnitude disparity under one exponent -> more
    distortion (the Fig 1 effect), for heavy-tailed data at m=4."""
    rng = np.random.default_rng(11)
    # Log-normal magnitudes create intra-block disparity.
    x = (rng.standard_normal(2304) * np.exp(rng.standard_normal(2304))).astype(np.float32)
    errs = [float(np.square(q_ref(x, b, 4) - x).sum()) for b in (16, 64, 576)]
    assert errs[0] <= errs[1] <= errs[2], errs


def test_stochastic_rounding_unbiased():
    """E[Q_sr(x)] ~= x: stochastic rounding is unbiased across seeds."""
    x = np.full(64, 0.3, np.float32)
    acc = np.zeros(64, np.float64)
    n = 400
    for seed in range(n):
        acc += q_ref(x, 64, 4, rmode=1.0, seed=float(seed))
    mean = acc / n
    # interval at e=-2, m=4 is 2^-4; mean error should be << interval/2
    assert abs(mean.mean() - 0.3) < 0.004, mean.mean()


def test_exponent_extraction_exact_at_powers_of_two():
    for e in (-10, -1, 0, 1, 7):
        x = np.array([2.0**e] * 16, np.float32)
        out = q_ref(x, 16, 6)
        np.testing.assert_array_equal(out, x)  # exact powers of two survive


def test_shared_exponent_kills_small_elements():
    """An element ≪ max in the same block quantizes to 0 at m=4 — the
    precision-loss mechanism of §2."""
    x = np.array([1024.0] + [1e-3] * 15, np.float32)
    out = q_ref(x, 16, 4)
    assert out[0] == 1024.0
    np.testing.assert_array_equal(out[1:], np.zeros(15, np.float32))


def test_pallas_fused_matmul_matches_tile_ref():
    """bfp_matmul_pallas == Q_tile(x) @ Q_tile(w) with tile-local blocking."""
    rng = np.random.default_rng(9)
    m, k, n, block = 32, 128, 32, 64
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(
        P.bfp_matmul_pallas(jnp.asarray(x), jnp.asarray(w), F32(4), F32(0), F32(7), block=block)
    )
    # Reference: quantize each (tile, bk) row-block with base_idx 0.
    def tq(t2d):  # rows are blocks of `block`
        blocks = t2d.reshape(-1, block)
        q = R.quantize_blocks(jnp.asarray(blocks), F32(4), F32(0.0), jnp.uint32(7), jnp.uint32(0))
        return np.asarray(q).reshape(t2d.shape)

    xq = tq(x)
    wq = tq(np.ascontiguousarray(w.T)).T
    np.testing.assert_allclose(got, xq @ wq, rtol=1e-6, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(axis=st.sampled_from([0, 1]), m=st.sampled_from([4, 6]))
def test_quantize_along_axis_blocks_run_along_axis(axis, m):
    """Blocking along an axis == blocking the transposed flat layout."""
    rng = np.random.default_rng(2)
    t = rng.standard_normal((12, 20)).astype(np.float32)
    got = np.asarray(
        R.quantize_along_axis(jnp.asarray(t), axis, 16, F32(m), F32(0.0), F32(7), 0)
    )
    moved = np.moveaxis(t, axis, -1)
    want = q_ref(moved.reshape(-1), 16, m).reshape(moved.shape)
    np.testing.assert_array_equal(got, np.moveaxis(want, -1, axis))
