"""AOT-layer tests: variant parsing, manifest consistency, HLO-text
emission shape (fast: uses the MLP, and checks an existing artifacts dir
when present rather than re-lowering everything)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, train
from compile.kernels import ref as R


def test_default_variant_set_covers_paper_axes():
    names = [v.name for v in aot.default_variants(quick=False)]
    # Full block axis for both image models.
    for b in aot.PAPER_BLOCK_SIZES:
        assert f"mlp_bs{b}" in names
        assert f"cnn_bs{b}" in names
    assert "transformer_bs64" in names
    assert "mlp_bs64_pallas" in names
    # Quick set is a strict subset.
    quick = [v.name for v in aot.default_variants(quick=True)]
    assert set(quick) <= set(names)


def test_variant_name_roundtrip():
    v = aot.Variant("cnn", 576)
    assert v.name == "cnn_bs576"
    vp = aot.Variant("mlp", 64, pallas=True)
    assert vp.name == "mlp_bs64_pallas"


def test_opt_spec_layouts():
    m = aot.build_model("mlp")
    sgd = train.opt_spec(m, "sgdm")
    assert len(sgd.slot_names) == len(m.builder.specs)
    adam = train.opt_spec(m, "adam")
    assert len(adam.slot_names) == 2 * len(m.builder.specs) + 1
    assert adam.slot_names[-1] == "adam_t"
    with pytest.raises(ValueError):
        train.opt_spec(m, "rmsprop")


def test_hlo_text_emission_is_parseable_text():
    model = aot.build_model("mlp")
    ts, _, ospec = train.make_fns(model, 64, "sgdm", R.quantize_flat)
    p = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.builder.specs]
    o = [jax.ShapeDtypeStruct(s, jnp.float32) for s in ospec.slot_shapes]
    x = jax.ShapeDtypeStruct((8, 48), jnp.float32)
    y = jax.ShapeDtypeStruct((8,), jnp.int32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(ts).lower(*(p + o + [x, y] + [f32] * 5))
    text = aot.to_hlo_text(lowered)
    # HLO text module header + a tuple root with the right arity.
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # All entry parameters present (fusion may add internal ones).
    assert text.count("parameter(") >= len(p) + len(o) + 2 + 5
    assert "tuple(" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/index.json")),
    reason="artifacts not built",
)
def test_built_artifacts_consistent_with_models():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "index.json")) as f:
        index = json.load(f)
    assert len(index["variants"]) >= 4
    for entry in index["variants"]:
        vdir = os.path.join(root, entry["name"])
        with open(os.path.join(vdir, "manifest.json")) as f:
            man = json.load(f)
        assert man["variant"] == entry["name"]
        assert man["block"] == entry["block"]
        model = aot.build_model(man["model"])
        assert len(man["params"]) == len(model.builder.specs)
        for spec, got in zip(model.builder.specs, man["params"]):
            assert got["name"] == spec.name
            assert tuple(got["shape"]) == tuple(spec.shape)
        for key, fname in man["artifacts"].items():
            path = os.path.join(vdir, fname)
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), (entry["name"], key)
