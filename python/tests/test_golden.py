"""The golden-vector file is reproducible and self-consistent: replaying
each stored input through ref.quantize_flat reproduces the stored output
bit-for-bit (the same check rust runs from the other side)."""

import numpy as np
import jax.numpy as jnp

from compile import golden
from compile.kernels import ref as R


def test_golden_replay_bitexact():
    data = golden.generate()
    assert len(data["cases"]) > 30
    for c in data["cases"]:
        x = np.asarray(c["input"], np.float32)
        out = R.quantize_flat(
            jnp.asarray(x),
            c["block"],
            jnp.float32(c["m_bits"]),
            jnp.float32(c["rmode"]),
            jnp.float32(c["seed"]),
            c["site"],
        )
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(c["output"], np.float32), err_msg=str(c)[:120]
        )


def test_golden_deterministic():
    a = golden.generate()
    b = golden.generate()
    assert a == b


def test_xorshift_vectors():
    data = golden.generate()
    idx = jnp.arange(64, dtype=jnp.uint32)
    for seed, want in data["xorshift"].items():
        got = R.xorshift_hash(idx, jnp.uint32(int(seed)))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want, np.uint32))
